//! Pareto-front utilities over (energy, latency) mapping points.

/// Returns the indices of the Pareto-optimal points (minimizing both
/// coordinates). Stable: preserves input order among non-dominated points.
/// Duplicates of a non-dominated point are all kept (neither dominates
/// the other — domination requires a strict improvement somewhere).
///
/// Sort-based O(n log n) scan (grids past ~10⁴ points made the old
/// all-pairs check a hot spot): walk the points in (energy, latency)
/// order; a point is dominated iff a strictly-cheaper point was at
/// least as fast, or an equal-energy point was strictly faster.
///
/// ```
/// use imcsim::dse::pareto_front;
///
/// // minimizing (energy, latency): (3.0, 6.0) loses to (2.0, 5.0)
/// let points = [(1.0, 10.0), (2.0, 5.0), (3.0, 6.0), (0.5, 20.0)];
/// assert_eq!(pareto_front(&points), vec![0, 1, 3]);
/// ```
pub fn pareto_front(points: &[(f64, f64)]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    idx.sort_by(|&a, &b| {
        points[a]
            .0
            .total_cmp(&points[b].0)
            .then(points[a].1.total_cmp(&points[b].1))
            .then(a.cmp(&b))
    });
    let mut out = Vec::new();
    // min latency among points with strictly smaller energy
    let mut best_t_prev = f64::INFINITY;
    let mut i = 0;
    while i < idx.len() {
        // group of equal-energy points, latency ascending. `j` starts
        // past `i`, so the loop always advances; a NaN energy (never
        // equal to anything, including itself) forms a singleton group
        // that is incomparable under `<=`, so it neither consults nor
        // feeds `best_t_prev` — matching the all-pairs definition,
        // which keeps NaN points.
        let e = points[idx[i]].0;
        let group_min_t = points[idx[i]].1;
        let mut j = i + 1;
        while j < idx.len() && points[idx[j]].0 == e {
            j += 1;
        }
        for &p in &idx[i..j] {
            let t = points[p].1;
            let dominated = (!e.is_nan() && best_t_prev <= t) || t > group_min_t;
            if !dominated {
                out.push(p);
            }
        }
        if !e.is_nan() && group_min_t < best_t_prev {
            best_t_prev = group_min_t;
        }
        i = j;
    }
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple_front() {
        let pts = [(1.0, 10.0), (2.0, 5.0), (3.0, 6.0), (0.5, 20.0)];
        let f = pareto_front(&pts);
        assert_eq!(f, vec![0, 1, 3]); // (3,6) dominated by (2,5)
    }

    #[test]
    fn duplicates_both_kept() {
        let pts = [(1.0, 1.0), (1.0, 1.0)];
        assert_eq!(pareto_front(&pts).len(), 2);
    }

    #[test]
    fn single_point() {
        assert_eq!(pareto_front(&[(4.0, 2.0)]), vec![0]);
    }

    #[test]
    fn empty() {
        assert!(pareto_front(&[]).is_empty());
    }

    #[test]
    fn strictly_dominated_removed() {
        let pts = [(1.0, 1.0), (2.0, 2.0)];
        assert_eq!(pareto_front(&pts), vec![0]);
    }

    #[test]
    fn equal_energy_keeps_only_fastest_and_its_duplicates() {
        let pts = [(1.0, 3.0), (1.0, 2.0), (1.0, 2.0), (1.0, 5.0)];
        assert_eq!(pareto_front(&pts), vec![1, 2]);
    }

    /// The naive O(n²) definition the scan must match exactly.
    fn reference(points: &[(f64, f64)]) -> Vec<usize> {
        let mut out = Vec::new();
        'outer: for (i, &(e_i, t_i)) in points.iter().enumerate() {
            for (j, &(e_j, t_j)) in points.iter().enumerate() {
                if i == j {
                    continue;
                }
                if e_j <= e_i && t_j <= t_i && (e_j < e_i || t_j < t_i) {
                    continue 'outer;
                }
            }
            out.push(i);
        }
        out
    }

    #[test]
    fn nan_points_kept_and_scan_terminates() {
        // every comparison with NaN is false, so the all-pairs
        // definition keeps NaN points; the scan must match and must not
        // hang on the never-equal group key
        let pts = [(f64::NAN, 1.0), (1.0, f64::NAN), (1.0, 2.0), (2.0, 1.0)];
        assert_eq!(pareto_front(&pts), reference(&pts));
    }

    #[test]
    fn scan_matches_naive_reference_on_random_grids() {
        let mut rng = crate::util::prng::Rng::new(7);
        for n in [1usize, 2, 3, 10, 64, 257] {
            let pts: Vec<(f64, f64)> = (0..n)
                .map(|_| {
                    // coarse values force plenty of exact ties/duplicates
                    let e = rng.below(8) as f64;
                    let t = rng.below(8) as f64;
                    (e, t)
                })
                .collect();
            assert_eq!(pareto_front(&pts), reference(&pts), "n={n}: {pts:?}");
        }
    }
}
