//! Pareto-front utilities over (energy, latency) mapping points.

/// Returns the indices of the Pareto-optimal points (minimizing both
/// coordinates). Stable: preserves input order among non-dominated points.
/// Duplicates of a non-dominated point are all kept (neither dominates
/// the other — domination requires a strict improvement somewhere).
///
/// Sort-based O(n log n) scan (grids past ~10⁴ points made the old
/// all-pairs check a hot spot): walk the points in (energy, latency)
/// order; a point is dominated iff a strictly-cheaper point was at
/// least as fast, or an equal-energy point was strictly faster.
///
/// ```
/// use imcsim::dse::pareto_front;
///
/// // minimizing (energy, latency): (3.0, 6.0) loses to (2.0, 5.0)
/// let points = [(1.0, 10.0), (2.0, 5.0), (3.0, 6.0), (0.5, 20.0)];
/// assert_eq!(pareto_front(&points), vec![0, 1, 3]);
/// ```
pub fn pareto_front(points: &[(f64, f64)]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    idx.sort_by(|&a, &b| {
        points[a]
            .0
            .total_cmp(&points[b].0)
            .then(points[a].1.total_cmp(&points[b].1))
            .then(a.cmp(&b))
    });
    let mut out = Vec::new();
    // min latency among points with strictly smaller energy
    let mut best_t_prev = f64::INFINITY;
    let mut i = 0;
    while i < idx.len() {
        // group of equal-energy points, latency ascending. `j` starts
        // past `i`, so the loop always advances; a NaN energy (never
        // equal to anything, including itself) forms a singleton group
        // that is incomparable under `<=`, so it neither consults nor
        // feeds `best_t_prev` — matching the all-pairs definition,
        // which keeps NaN points.
        let e = points[idx[i]].0;
        let group_min_t = points[idx[i]].1;
        let mut j = i + 1;
        while j < idx.len() && points[idx[j]].0 == e {
            j += 1;
        }
        for &p in &idx[i..j] {
            let t = points[p].1;
            let dominated = (!e.is_nan() && best_t_prev <= t) || t > group_min_t;
            if !dominated {
                out.push(p);
            }
        }
        if !e.is_nan() && group_min_t < best_t_prev {
            best_t_prev = group_min_t;
        }
        i = j;
    }
    out.sort_unstable();
    out
}

/// Indices of the Pareto-optimal points of a 3-objective minimization
/// (e.g. the sweep's (energy, latency, −SQNR) surface). Same semantics
/// as [`pareto_front`] lifted to three coordinates: a point is
/// dominated iff some other point is ≤ on every axis and < on at least
/// one; duplicates of a non-dominated point are all kept, NaN points
/// are incomparable and kept, and input order is preserved.
///
/// The scan sorts by the first axis and only tests candidate dominators
/// with a smaller-or-equal first coordinate — `O(n·k)` where `k` is the
/// prefix of cheaper points, which the grid-sized inputs here (10³–10⁴
/// points, most of them dominated early) keep far from the all-pairs
/// worst case. A full sort-free 3D skyline structure is not warranted
/// at this scale.
///
/// ```
/// use imcsim::dse::pareto_front_3d;
///
/// let pts = [(1.0, 1.0, 9.0), (2.0, 2.0, 9.0), (3.0, 3.0, 1.0)];
/// // (2,2,9) is dominated by (1,1,9); (3,3,1) survives on the 3rd axis
/// assert_eq!(pareto_front_3d(&pts), vec![0, 2]);
/// ```
pub fn pareto_front_3d(points: &[(f64, f64, f64)]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..points.len()).collect();
    order.sort_by(|&a, &b| {
        points[a]
            .0
            .total_cmp(&points[b].0)
            .then(points[a].1.total_cmp(&points[b].1))
            .then(points[a].2.total_cmp(&points[b].2))
            .then(a.cmp(&b))
    });
    let dominates = |d: (f64, f64, f64), p: (f64, f64, f64)| {
        d.0 <= p.0
            && d.1 <= p.1
            && d.2 <= p.2
            && (d.0 < p.0 || d.1 < p.1 || d.2 < p.2)
    };
    let mut out = Vec::new();
    for (pos, &i) in order.iter().enumerate() {
        let p = points[i];
        // only points sorted before `i` can have a smaller (or equal)
        // first coordinate; anything later is ≥ on axis 0 and would
        // need to be strictly better elsewhere while tying axis 0 —
        // covered by the equal-first-coordinate prefix neighbors, which
        // also sort before `i` unless they tie on all three axes (then
        // neither dominates)
        let dominated = order[..pos].iter().any(|&j| dominates(points[j], p));
        if !dominated {
            out.push(i);
        }
    }
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple_front() {
        let pts = [(1.0, 10.0), (2.0, 5.0), (3.0, 6.0), (0.5, 20.0)];
        let f = pareto_front(&pts);
        assert_eq!(f, vec![0, 1, 3]); // (3,6) dominated by (2,5)
    }

    #[test]
    fn duplicates_both_kept() {
        let pts = [(1.0, 1.0), (1.0, 1.0)];
        assert_eq!(pareto_front(&pts).len(), 2);
    }

    #[test]
    fn single_point() {
        assert_eq!(pareto_front(&[(4.0, 2.0)]), vec![0]);
    }

    #[test]
    fn empty() {
        assert!(pareto_front(&[]).is_empty());
    }

    #[test]
    fn strictly_dominated_removed() {
        let pts = [(1.0, 1.0), (2.0, 2.0)];
        assert_eq!(pareto_front(&pts), vec![0]);
    }

    #[test]
    fn equal_energy_keeps_only_fastest_and_its_duplicates() {
        let pts = [(1.0, 3.0), (1.0, 2.0), (1.0, 2.0), (1.0, 5.0)];
        assert_eq!(pareto_front(&pts), vec![1, 2]);
    }

    /// The naive O(n²) definition the scan must match exactly.
    fn reference(points: &[(f64, f64)]) -> Vec<usize> {
        let mut out = Vec::new();
        'outer: for (i, &(e_i, t_i)) in points.iter().enumerate() {
            for (j, &(e_j, t_j)) in points.iter().enumerate() {
                if i == j {
                    continue;
                }
                if e_j <= e_i && t_j <= t_i && (e_j < e_i || t_j < t_i) {
                    continue 'outer;
                }
            }
            out.push(i);
        }
        out
    }

    #[test]
    fn nan_points_kept_and_scan_terminates() {
        // every comparison with NaN is false, so the all-pairs
        // definition keeps NaN points; the scan must match and must not
        // hang on the never-equal group key
        let pts = [(f64::NAN, 1.0), (1.0, f64::NAN), (1.0, 2.0), (2.0, 1.0)];
        assert_eq!(pareto_front(&pts), reference(&pts));
    }

    /// The naive O(n²) 3-objective definition the scan must match.
    fn reference_3d(points: &[(f64, f64, f64)]) -> Vec<usize> {
        let mut out = Vec::new();
        'outer: for (i, &(x, y, z)) in points.iter().enumerate() {
            for (j, &(a, b, c)) in points.iter().enumerate() {
                if i == j {
                    continue;
                }
                if a <= x && b <= y && c <= z && (a < x || b < y || c < z) {
                    continue 'outer;
                }
            }
            out.push(i);
        }
        out
    }

    #[test]
    fn front_3d_keeps_per_axis_minima_and_drops_dominated() {
        let pts = [
            (1.0, 9.0, 9.0), // energy minimum
            (9.0, 1.0, 9.0), // latency minimum
            (9.0, 9.0, 1.0), // error minimum
            (2.0, 2.0, 2.0), // balanced, non-dominated
            (3.0, 3.0, 3.0), // dominated by the balanced point
        ];
        assert_eq!(pareto_front_3d(&pts), vec![0, 1, 2, 3]);
    }

    #[test]
    fn front_3d_duplicates_ties_empty_and_nan() {
        assert!(pareto_front_3d(&[]).is_empty());
        assert_eq!(pareto_front_3d(&[(4.0, 2.0, 1.0)]), vec![0]);
        // duplicates of a non-dominated point are all kept
        let dup = [(1.0, 1.0, 1.0), (1.0, 1.0, 1.0), (2.0, 1.0, 1.0)];
        assert_eq!(pareto_front_3d(&dup), vec![0, 1]);
        // a tie on two axes with strict improvement on the third kills
        let two_tied = [(1.0, 1.0, 5.0), (1.0, 1.0, 4.0)];
        assert_eq!(pareto_front_3d(&two_tied), vec![1]);
        // NaN points are incomparable: kept, and never dominating
        let nan = [(f64::NAN, 1.0, 1.0), (1.0, f64::NAN, 2.0), (1.0, 2.0, 2.0), (2.0, 1.0, 1.0)];
        assert_eq!(pareto_front_3d(&nan), reference_3d(&nan));
    }

    #[test]
    fn front_3d_exact_points_at_neg_infinity_survive() {
        // an exact datapath sits at −∞ on the −SQNR axis: it can only
        // be dominated by another exact point that is cheaper/faster
        let pts = [
            (5.0, 5.0, f64::NEG_INFINITY),
            (1.0, 1.0, -30.0),
            (6.0, 6.0, f64::NEG_INFINITY), // dominated by the first
        ];
        assert_eq!(pareto_front_3d(&pts), vec![0, 1]);
    }

    #[test]
    fn front_3d_matches_naive_reference_on_random_grids() {
        let mut rng = crate::util::prng::Rng::new(13);
        for n in [1usize, 2, 3, 10, 64, 257] {
            let pts: Vec<(f64, f64, f64)> = (0..n)
                .map(|_| {
                    // coarse values force plenty of exact ties/duplicates
                    (
                        rng.below(6) as f64,
                        rng.below(6) as f64,
                        rng.below(6) as f64,
                    )
                })
                .collect();
            assert_eq!(pareto_front_3d(&pts), reference_3d(&pts), "n={n}: {pts:?}");
        }
    }

    #[test]
    fn front_3d_degenerate_third_axis_matches_2d_front() {
        // with a constant third coordinate the 3D front reduces to the
        // 2D front over the first two axes
        let mut rng = crate::util::prng::Rng::new(21);
        let pts2: Vec<(f64, f64)> = (0..64)
            .map(|_| (rng.below(8) as f64, rng.below(8) as f64))
            .collect();
        let pts3: Vec<(f64, f64, f64)> = pts2.iter().map(|&(x, y)| (x, y, 7.0)).collect();
        assert_eq!(pareto_front_3d(&pts3), pareto_front(&pts2));
    }

    #[test]
    fn scan_matches_naive_reference_on_random_grids() {
        let mut rng = crate::util::prng::Rng::new(7);
        for n in [1usize, 2, 3, 10, 64, 257] {
            let pts: Vec<(f64, f64)> = (0..n)
                .map(|_| {
                    // coarse values force plenty of exact ties/duplicates
                    let e = rng.below(8) as f64;
                    let t = rng.below(8) as f64;
                    (e, t)
                })
                .collect();
            assert_eq!(pareto_front(&pts), reference(&pts), "n={n}: {pts:?}");
        }
    }
}
