//! Technology-dependent parameter extraction (paper §IV-E, Fig. 6).
//!
//! All capacitances in the unified model are expressed relative to a
//! reference inverter capacitance `C_inv`. Following the paper, `C_inv`
//! values fitted per published DIMC design are linearly regressed across
//! technology nodes (Fig. 6a/6b); the DAC energy constant `k3` is fitted
//! across AIMC DAC-based designs (Fig. 6c).

/// Murmann ADC model constant `k1` (fJ per bit of resolution), paper Eq. 8.
pub const K1_FJ: f64 = 100.0;
/// Murmann ADC model constant `k2` (fJ; paper: 1 aJ = 1e-3 fJ), Eq. 8.
pub const K2_FJ: f64 = 1e-3;
/// DAC energy per conversion step (fJ), fitted in Fig. 6c, Eq. 11.
pub const K3_FJ: f64 = 44.0;
/// Gates per 1-bit full adder (paper §IV-C: assumed 5).
pub const G_FA: f64 = 5.0;
/// Gates per 1-bit multiplier (paper §IV-B: single NAND/NOR, ~1).
pub const G_MUL_1B: f64 = 1.0;

/// Per-design fitted `C_inv` points (node nm, fitted C_inv fF) used for
/// the Fig. 6a/6b regression. The fits correspond to the DIMC designs the
/// paper lists for this purpose ([40] 22 nm, [41] 5 nm, [42] 28 nm,
/// [44] 65 nm near-memory). Values are this reproduction's fits (fF).
pub const FITTED_CINV_POINTS: [(f64, f64, &str); 4] = [
    (5.0, 0.095, "fujiwara_isscc22"),
    (22.0, 0.325, "chih_isscc21"),
    (28.0, 0.405, "tu_isscc22"),
    (65.0, 0.980, "problp_dac19"),
];

/// Per-design fitted DAC energy/conversion-step points (node nm, fJ) for
/// the Fig. 6c fit of `k3` across AIMC DAC-based designs.
pub const FITTED_DAC_POINTS: [(f64, f64, &str); 3] = [
    (22.0, 40.0, "papistas_cicc21"),
    (16.0, 43.0, "jia_isscc21"),
    (28.0, 49.0, "su_isscc21"),
];

/// Technology-dependent capacitance parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TechParams {
    /// Reference inverter capacitance (fF).
    pub c_inv_ff: f64,
    /// Standard logic gate capacitance (fF) — paper: ≈ 2 × C_inv.
    pub c_gate_ff: f64,
    /// Wordline capacitance per cell (fF) — paper: ≈ C_inv.
    pub c_wl_ff: f64,
    /// Bitline capacitance per cell (fF) — paper: ≈ C_inv.
    pub c_bl_ff: f64,
}

impl TechParams {
    /// Build parameters for a technology node from the Fig. 6 regression.
    pub fn for_node(tech_nm: f64) -> Self {
        let c_inv = c_inv_ff(tech_nm);
        TechParams {
            c_inv_ff: c_inv,
            c_gate_ff: 2.0 * c_inv,
            c_wl_ff: c_inv,
            c_bl_ff: c_inv,
        }
    }
}

/// Ordinary least-squares linear fit `y = slope * x + intercept`.
pub fn linear_fit(points: &[(f64, f64)]) -> (f64, f64) {
    let n = points.len() as f64;
    assert!(n >= 2.0, "need at least two points for a fit");
    let sx: f64 = points.iter().map(|p| p.0).sum();
    let sy: f64 = points.iter().map(|p| p.1).sum();
    let sxx: f64 = points.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = points.iter().map(|p| p.0 * p.1).sum();
    let slope = (n * sxy - sx * sy) / (n * sxx - sx * sx);
    let intercept = (sy - slope * sx) / n;
    (slope, intercept)
}

/// Regressed `C_inv(node)` in fF (Fig. 6a/6b line).
pub fn c_inv_ff(tech_nm: f64) -> f64 {
    let pts: Vec<(f64, f64)> = FITTED_CINV_POINTS.iter().map(|p| (p.0, p.1)).collect();
    let (slope, intercept) = linear_fit(&pts);
    (slope * tech_nm + intercept).max(0.01)
}

/// Fitted DAC fJ/conversion-step (Fig. 6c): the mean of the per-design
/// fits — the paper reports `k3 ≈ 44 fJ` with ~9 % average mismatch.
pub fn fitted_k3_fj() -> f64 {
    let s: f64 = FITTED_DAC_POINTS.iter().map(|p| p.1).sum();
    s / FITTED_DAC_POINTS.len() as f64
}

/// Relative mismatch of each fitted C_inv point vs the regression line
/// (the "~10 % model mismatch" of §IV-E).
pub fn cinv_fit_mismatches() -> Vec<(f64, f64, &'static str)> {
    FITTED_CINV_POINTS
        .iter()
        .map(|&(node, fitted, name)| {
            let modeled = c_inv_ff(node);
            (node, (modeled - fitted).abs() / fitted, name)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_fit_recovers_exact_line() {
        let pts = [(1.0, 3.0), (2.0, 5.0), (3.0, 7.0)];
        let (m, b) = linear_fit(&pts);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((b - 1.0).abs() < 1e-12);
    }

    #[test]
    fn c_inv_monotone_in_node() {
        assert!(c_inv_ff(5.0) < c_inv_ff(22.0));
        assert!(c_inv_ff(22.0) < c_inv_ff(65.0));
        // plausible magnitudes (fF)
        assert!(c_inv_ff(28.0) > 0.1 && c_inv_ff(28.0) < 1.0);
    }

    #[test]
    fn c_inv_never_negative() {
        assert!(c_inv_ff(1.0) >= 0.01);
    }

    #[test]
    fn k3_close_to_paper_value() {
        // the paper sets k3 = 44 fJ from the same style of fit
        assert!((fitted_k3_fj() - K3_FJ).abs() / K3_FJ < 0.05);
    }

    #[test]
    fn cinv_regression_mismatch_band() {
        // §IV-E reports ~10 % mismatch; our fit should stay in that band
        for (node, mismatch, name) in cinv_fit_mismatches() {
            assert!(
                mismatch < 0.20,
                "{name} at {node} nm has {:.0} % mismatch",
                mismatch * 100.0
            );
        }
    }

    #[test]
    fn tech_params_derived_ratios() {
        let t = TechParams::for_node(28.0);
        assert_eq!(t.c_gate_ff, 2.0 * t.c_inv_ff);
        assert_eq!(t.c_wl_ff, t.c_inv_ff);
        assert_eq!(t.c_bl_ff, t.c_inv_ff);
    }
}
