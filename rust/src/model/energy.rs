//! The unified AIMC/DIMC datapath energy model (paper §IV, Eqs. 1–11).
//!
//! ```text
//! E_total = E_MUL + E_ACC + E_peripherals                         (Eq. 1)
//! E_MUL   = E_cell + E_logic                                      (Eq. 2)
//! E_cell  = (E_WL + E_BL) · CC_prech                              (Eq. 3)
//! E_WL    = C_WL · V² · B_w · D1  [· active rows]                 (Eq. 4)
//! E_BL    = C_BL · V² · B_w · D2 · M  [· D1 bitline groups]       (Eq. 5)
//! E_logic = V² · C_gate · G_MUL · MACs          (DIMC only)       (Eq. 6)
//! E_ACC   = E_ADC + E_adder_tree                                  (Eq. 7)
//! E_ADC   = (k1·res + k2·4^res) · V² · B_w · MACs/D2  (AIMC)      (Eq. 8)
//! E_tree  = C_gate · G_FA · V² · D1 · F · CC_acc                  (Eq. 9)
//! F       = B·N + N − B + log2 N − 1                              (Eq. 10)
//! E_DAC   = k3 · DAC_res · V² · CC_BS             (AIMC)          (Eq. 11)
//! ```
//!
//! **Interpretation choices** (the paper writes Eqs. 4–5 per wordline /
//! per bitline group; we evaluate them at array level per compute cycle —
//! see DESIGN.md §6):
//!
//! * AIMC toggles all active rows' wordlines and all bitlines every
//!   compute cycle (`CC_prech` = every bit-serial step of every MVM).
//! * DIMC keeps weights stationary on the bitlines; `CC_prech` counts
//!   only weight-(re)load events. The per-cycle input broadcast and
//!   multiply energy is `E_logic` (Eq. 6) with `G_MUL = B_w` gates per
//!   operand MAC per input slice.
//! * Input sparsity (the paper's surveys assume 50 %) scales the
//!   input-dependent switching terms (WL drive, logic, adder tree).
//! * Underutilization: wordline/bitline capacitance is charged over the
//!   *physical* array span; converters and trees only fire on *used*
//!   columns/rows. Unused-column energy is the large-array penalty the
//!   case studies expose.

use crate::arch::{ImcFamily, ImcMacro};

use super::adc;
use super::adder_tree;
use super::dac;
use super::tech::{TechParams, G_FA, G_MUL_1B};

/// Mapping-dependent operation counts for one macro executing (part of) a
/// layer (Table I "mapping dependent extracted parameters").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MacroOpCounts {
    /// Full-array MVM invocations (each spans `n_slices` compute cycles).
    pub mvms: u64,
    /// Full-array weight (re)load events.
    pub weight_loads: u64,
    /// Average rows used per MVM (≤ D2·M; drives converter/tree counts).
    pub rows_used: f64,
    /// Average weight operands used per row (≤ D1).
    pub cols_used: f64,
    /// Fraction of input bits that are zero (no switching). The survey
    /// comparisons use 0.5.
    pub input_sparsity: f64,
}

impl MacroOpCounts {
    /// Peak workload: array fully used, weights stationary.
    pub fn peak(m: &ImcMacro, mvms: u64, input_sparsity: f64) -> Self {
        MacroOpCounts {
            mvms,
            weight_loads: 0,
            rows_used: m.rows as f64,
            cols_used: m.d1() as f64,
            input_sparsity,
        }
    }

    /// Useful full-precision MACs represented by these counts.
    pub fn useful_macs(&self) -> f64 {
        self.mvms as f64 * self.rows_used * self.cols_used
    }

    /// Sanity-check the counts against the macro's geometry.
    pub fn validate(&self, m: &ImcMacro) -> Result<(), String> {
        if self.rows_used < 0.0 || self.rows_used > m.rows as f64 {
            return Err(format!("rows_used {} out of [0, {}]", self.rows_used, m.rows));
        }
        if self.cols_used < 0.0 || self.cols_used > m.d1() as f64 {
            return Err(format!("cols_used {} out of [0, {}]", self.cols_used, m.d1()));
        }
        if !(0.0..=1.0).contains(&self.input_sparsity) {
            return Err(format!("input_sparsity {} out of [0,1]", self.input_sparsity));
        }
        Ok(())
    }
}

/// Per-component datapath energy (fJ) — the Fig. 7 breakdown categories.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct EnergyBreakdown {
    /// Wordline charging (part of E_cell).
    pub wl_fj: f64,
    /// Bitline charging (part of E_cell; analog accumulation for AIMC).
    pub bl_fj: f64,
    /// Cell-adjacent multiplier gates (DIMC only, Eq. 6).
    pub logic_fj: f64,
    /// A/D conversion (AIMC only, Eq. 8).
    pub adc_fj: f64,
    /// Digital adder tree (Eq. 9).
    pub adder_tree_fj: f64,
    /// D/A conversion (AIMC only, Eq. 11).
    pub dac_fj: f64,
    /// Weight (re)load writes into the array.
    pub weight_load_fj: f64,
}

impl EnergyBreakdown {
    /// E_total (Eq. 1) + weight loading.
    pub fn total_fj(&self) -> f64 {
        self.wl_fj
            + self.bl_fj
            + self.logic_fj
            + self.adc_fj
            + self.adder_tree_fj
            + self.dac_fj
            + self.weight_load_fj
    }

    /// E_MUL (Eq. 2).
    pub fn e_mul_fj(&self) -> f64 {
        self.wl_fj + self.bl_fj + self.logic_fj
    }

    /// E_ACC (Eq. 7).
    pub fn e_acc_fj(&self) -> f64 {
        self.adc_fj + self.adder_tree_fj
    }

    /// E_peripherals (Eq. 11 contribution).
    pub fn e_peripherals_fj(&self) -> f64 {
        self.dac_fj
    }

    /// Every component scaled by `k` (e.g. × active macros).
    pub fn scaled(&self, k: f64) -> Self {
        EnergyBreakdown {
            wl_fj: self.wl_fj * k,
            bl_fj: self.bl_fj * k,
            logic_fj: self.logic_fj * k,
            adc_fj: self.adc_fj * k,
            adder_tree_fj: self.adder_tree_fj * k,
            dac_fj: self.dac_fj * k,
            weight_load_fj: self.weight_load_fj * k,
        }
    }

    /// Accumulate another breakdown component-wise.
    pub fn add(&mut self, other: &EnergyBreakdown) {
        self.wl_fj += other.wl_fj;
        self.bl_fj += other.bl_fj;
        self.logic_fj += other.logic_fj;
        self.adc_fj += other.adc_fj;
        self.adder_tree_fj += other.adder_tree_fj;
        self.dac_fj += other.dac_fj;
        self.weight_load_fj += other.weight_load_fj;
    }
}

/// Energy to (re)write the full weight array once: every cell sees a
/// wordline pulse and a bitline swing (read-modify-write style drive).
fn full_array_write_fj(m: &ImcMacro, t: &TechParams) -> f64 {
    let v2 = m.vdd * m.vdd;
    let cells = (m.rows * m.cols) as f64;
    (t.c_wl_ff + t.c_bl_ff) * v2 * cells
}

/// Evaluate the unified model for one macro and one set of op counts.
pub fn macro_energy(m: &ImcMacro, t: &TechParams, ops: &MacroOpCounts) -> EnergyBreakdown {
    debug_assert!(ops.validate(m).is_ok(), "{:?}", ops.validate(m));
    let v2 = m.vdd * m.vdd;
    let bw = m.weight_bits as f64;
    let d1_phys = m.d1() as f64;
    let d2_phys = m.d2() as f64;
    let mrows = m.row_mux as f64;
    let slices = m.n_slices() as f64;
    let mvms = ops.mvms as f64;
    let act = 1.0 - ops.input_sparsity;
    let rows_used = ops.rows_used;
    let cols_used = ops.cols_used;

    let mut e = EnergyBreakdown::default();

    match m.family {
        ImcFamily::Aimc => {
            // Eq. 3–5, array level per compute cycle: active rows' WLs
            // toggle with the (sparse) input, all physical bitline spans
            // share charge. CC_prech = slices · mvms.
            let cc_prech = slices * mvms;
            // Eq. 4 ·(active rows): wordline cap across the full row span
            // (B_w · D1_phys cells) — unused columns still load the WL.
            e.wl_fj = t.c_wl_ff * v2 * bw * d1_phys * rows_used * cc_prech * act;
            // Eq. 5 ·(D1 bitline groups): all physical bitlines swing.
            e.bl_fj = t.c_bl_ff * v2 * bw * d1_phys * d2_phys * mrows * cc_prech;
            // Eq. 8: one conversion per *used* bitline (power-gated
            // otherwise), per compute cycle.
            let adcs = (cols_used * bw / m.cols_per_adc as f64) * cc_prech;
            e.adc_fj = adc::conversion_energy_fj_at(m.adc_res, m.vdd, m.tech_nm) * adcs;
            // Eq. 11: one DAC conversion per used row per cycle (CC_BS).
            let cc_bs = rows_used * cc_prech;
            e.dac_fj = dac::conversion_energy_fj(m.dac_res, m.vdd) * cc_bs;
            // Eq. 9–10: shift-add recombination across B_w bitline ADC
            // results (N = B_w, B = ADC_res), one tree per used operand
            // column per cycle.
            let f = adder_tree::recombination_full_adders(m.weight_bits, m.adc_res);
            e.adder_tree_fj = t.c_gate_ff * G_FA * v2 * f * cols_used * cc_prech * act;
        }
        ImcFamily::Dimc => {
            // Weights stationary: bitlines only toggle on weight loads
            // (CC_prech = weight_loads) — folded into weight_load_fj.
            // Eq. 6: one NAND per weight bit per used operand pair per
            // input slice; sparsity gates switching.
            let gmul = G_MUL_1B * bw;
            let macs_slices = cols_used * rows_used / mrows * slices * mvms * mrows;
            e.logic_fj = t.c_gate_ff * gmul * v2 * macs_slices * act;
            // Eq. 9–10: accumulation across D2 rows (N = D2, B = B_w),
            // one tree per used operand column, per compute cycle
            // (slices · row-mux steps per MVM).
            let f = adder_tree::accumulation_full_adders(m.d2(), m.weight_bits);
            let cc_acc = slices * mrows * mvms;
            let row_activity = (rows_used / (d2_phys * mrows)).min(1.0);
            e.adder_tree_fj =
                t.c_gate_ff * G_FA * v2 * f * cols_used * cc_acc * act * row_activity;
        }
    }

    e.weight_load_fj = full_array_write_fj(m, t) * ops.weight_loads as f64;
    e
}

/// Peak datapath energy per full-precision MAC (fJ/MAC) at the given
/// input sparsity — the quantity behind the survey's TOP/s/W axis
/// (1 MAC = 2 OP).
pub fn peak_energy_per_mac_fj(m: &ImcMacro, t: &TechParams, input_sparsity: f64) -> f64 {
    let ops = MacroOpCounts::peak(m, 1, input_sparsity);
    let e = macro_energy(m, t, &ops);
    e.total_fj() / ops.useful_macs()
}

/// Peak energy efficiency in TOP/s/W (2 ops per MAC): `2 / (fJ/MAC) * 1e3`
/// gives TOPS/W when energy is in fJ.
pub fn peak_tops_per_watt(m: &ImcMacro, t: &TechParams, input_sparsity: f64) -> f64 {
    2.0e3 / peak_energy_per_mac_fj(m, t, input_sparsity)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ImcFamily;

    fn tech(node: f64) -> TechParams {
        TechParams::for_node(node)
    }

    fn aimc_large() -> ImcMacro {
        ImcMacro::new("a", ImcFamily::Aimc, 1152, 256, 4, 4, 4, 8, 0.8, 28.0)
    }

    fn dimc_chih() -> ImcMacro {
        ImcMacro::new("d", ImcFamily::Dimc, 64, 256, 4, 4, 1, 0, 0.8, 22.0)
    }

    #[test]
    fn aimc_has_converter_energy_dimc_does_not() {
        let t = tech(28.0);
        let a = macro_energy(&aimc_large(), &t, &MacroOpCounts::peak(&aimc_large(), 10, 0.5));
        assert!(a.adc_fj > 0.0 && a.dac_fj > 0.0);
        assert_eq!(a.logic_fj, 0.0);

        let ops = MacroOpCounts::peak(&dimc_chih(), 10, 0.5);
        let d = macro_energy(&dimc_chih(), &tech(22.0), &ops);
        assert_eq!(d.adc_fj, 0.0);
        assert_eq!(d.dac_fj, 0.0);
        assert!(d.logic_fj > 0.0 && d.adder_tree_fj > 0.0);
    }

    #[test]
    fn energy_linear_in_mvms() {
        let m = aimc_large();
        let t = tech(28.0);
        let e1 = macro_energy(&m, &t, &MacroOpCounts::peak(&m, 1, 0.5)).total_fj();
        let e10 = macro_energy(&m, &t, &MacroOpCounts::peak(&m, 10, 0.5)).total_fj();
        assert!((e10 / e1 - 10.0).abs() < 1e-9);
    }

    #[test]
    fn sparsity_reduces_switching_terms_only() {
        let m = dimc_chih();
        let t = tech(22.0);
        let dense = macro_energy(&m, &t, &MacroOpCounts::peak(&m, 1, 0.0));
        let sparse = macro_energy(&m, &t, &MacroOpCounts::peak(&m, 1, 0.5));
        assert!((sparse.logic_fj / dense.logic_fj - 0.5).abs() < 1e-9);
        assert!((sparse.adder_tree_fj / dense.adder_tree_fj - 0.5).abs() < 1e-9);

        let a = aimc_large();
        let t28 = tech(28.0);
        let ad = macro_energy(&a, &t28, &MacroOpCounts::peak(&a, 1, 0.0));
        let asp = macro_energy(&a, &t28, &MacroOpCounts::peak(&a, 1, 0.5));
        // bitlines + converters are not input-gated
        assert_eq!(ad.bl_fj, asp.bl_fj);
        assert_eq!(ad.adc_fj, asp.adc_fj);
        assert_eq!(ad.dac_fj, asp.dac_fj);
        assert!(asp.wl_fj < ad.wl_fj);
    }

    #[test]
    fn underutilization_hurts_aimc_energy_per_mac() {
        // Half the rows used: BL energy unchanged, useful MACs halved →
        // fJ/MAC strictly worse than full utilization.
        let m = aimc_large();
        let t = tech(28.0);
        let full = MacroOpCounts::peak(&m, 1, 0.5);
        let half = MacroOpCounts {
            rows_used: m.rows as f64 / 2.0,
            ..full
        };
        let e_full = macro_energy(&m, &t, &full).total_fj() / full.useful_macs();
        let e_half = macro_energy(&m, &t, &half).total_fj() / half.useful_macs();
        assert!(e_half > e_full * 1.2, "full {e_full} vs half {e_half}");
    }

    #[test]
    fn dimc_weight_reload_costs() {
        let m = dimc_chih();
        let t = tech(22.0);
        let stationary = MacroOpCounts::peak(&m, 100, 0.5);
        let mut reload = stationary;
        reload.weight_loads = 100;
        let e0 = macro_energy(&m, &t, &stationary).total_fj();
        let e1 = macro_energy(&m, &t, &reload).total_fj();
        assert!(e1 > e0);
    }

    #[test]
    fn peak_efficiency_plausible_bands() {
        // DIMC (Chih et al. '21-like, 22 nm 4b/4b): tens of TOPS/W
        let d = dimc_chih();
        let eff_d = peak_tops_per_watt(&d, &tech(22.0), 0.5);
        assert!(
            (30.0..300.0).contains(&eff_d),
            "DIMC peak {eff_d} TOPS/W out of band"
        );
        // AIMC large array: hundreds of TOPS/W, better than DIMC
        let a = aimc_large();
        let eff_a = peak_tops_per_watt(&a, &tech(28.0), 0.5);
        assert!(
            (100.0..3000.0).contains(&eff_a),
            "AIMC peak {eff_a} TOPS/W out of band"
        );
        assert!(eff_a > eff_d);
    }

    #[test]
    fn breakdown_component_sums() {
        let m = aimc_large();
        let t = tech(28.0);
        let e = macro_energy(&m, &t, &MacroOpCounts::peak(&m, 3, 0.5));
        let total = e.e_mul_fj() + e.e_acc_fj() + e.e_peripherals_fj() + e.weight_load_fj;
        assert!((total - e.total_fj()).abs() < 1e-9);
    }

    #[test]
    fn op_count_validation() {
        let m = aimc_large();
        let mut ops = MacroOpCounts::peak(&m, 1, 0.5);
        assert!(ops.validate(&m).is_ok());
        ops.rows_used = 1e9;
        assert!(ops.validate(&m).is_err());
        ops.rows_used = 10.0;
        ops.input_sparsity = 1.5;
        assert!(ops.validate(&m).is_err());
    }
}
