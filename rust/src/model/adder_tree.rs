//! Adder-tree model (paper §IV-C, Eqs. 9–10).
//!
//! A binary reduction tree with `N` first-stage inputs of `B` bits each.
//! Stage `n` (1-based) has `N / 2^n` adders of width `B + n - 1`, so the
//! number of 1-bit full adders per complete reduction is
//!
//! ```text
//! F = Σ_{n=1}^{log2 N} (B + n - 1) · N / 2^n  =  B·N + N − B + log2 N − 1
//! ```

/// Number of 1-bit full-adder operations per complete tree reduction
/// (Eq. 10). `n_inputs` is rounded up to the next power of two, matching
/// a physical tree with padded inputs.
pub fn full_adders(n_inputs: usize, input_bits: u32) -> f64 {
    if n_inputs <= 1 {
        return 0.0;
    }
    let n = n_inputs.next_power_of_two() as f64;
    let b = input_bits as f64;
    b * n + n - b - n.log2() - 1.0
}

/// Closed-form check value via the explicit stage sum (used by tests and
/// property checks; same rounding convention as [`full_adders`]).
pub fn full_adders_stage_sum(n_inputs: usize, input_bits: u32) -> f64 {
    if n_inputs <= 1 {
        return 0.0;
    }
    let n = n_inputs.next_power_of_two();
    let stages = (n as f64).log2() as u32;
    let b = input_bits as f64;
    let mut total = 0.0;
    for stage in 1..=stages {
        let adders = (n >> stage) as f64;
        let width = b + stage as f64 - 1.0;
        total += adders * width;
    }
    total
}

/// 1-bit full adders of the *DIMC accumulation* tree: D2 first-stage
/// inputs whose width is the weight precision. This is the term that
/// makes DIMC pay adder-width energy when weights get wider — the
/// digital counterpart of AIMC's ADC-resolution cost (precision
/// contract, `docs/COST_MODEL.md`).
pub fn accumulation_full_adders(d2: usize, weight_bits: u32) -> f64 {
    full_adders(d2, weight_bits)
}

/// 1-bit full adders of the *AIMC shift-add recombination* tree: one
/// `adc_res`-bit input per weight bit-slice (B_w inputs), so the
/// recombination cost scales with both the weight precision and the
/// re-derived ADC resolution.
pub fn recombination_full_adders(weight_bits: u32, adc_res: u32) -> f64 {
    full_adders(weight_bits as usize, adc_res)
}

/// Tree depth in adder stages.
pub fn depth(n_inputs: usize) -> u32 {
    if n_inputs <= 1 {
        0
    } else {
        (n_inputs.next_power_of_two() as f64).log2() as u32
    }
}

/// Output width of the tree (bits): input width + log2(N) carry growth.
pub fn output_bits(n_inputs: usize, input_bits: u32) -> u32 {
    input_bits + depth(n_inputs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closed_form_matches_stage_sum() {
        for n in [2usize, 4, 8, 16, 64, 256, 1024] {
            for b in [1u32, 4, 8, 12] {
                let cf = full_adders(n, b);
                let ss = full_adders_stage_sum(n, b);
                assert!(
                    (cf - ss).abs() < 1e-9,
                    "N={n} B={b}: closed-form {cf} != stage sum {ss}"
                );
            }
        }
    }

    #[test]
    fn paper_example_values() {
        // Eq. 10 (sign-corrected) with N=64, B=4: 4*64 + 64 - 4 - 6 - 1 = 309
        assert_eq!(full_adders(64, 4), 309.0);
        // N=B_w=4, B=ADC_res=8 (AIMC recombination): 8*4+4-8-2-1 = 25
        assert_eq!(full_adders(4, 8), 25.0);
    }

    #[test]
    fn precision_wrappers_delegate_to_the_tree_sum() {
        // the named trees are the same Eq. 10 kernel with the operand
        // roles pinned down — bit-identical to the raw call
        assert_eq!(accumulation_full_adders(256, 4), full_adders(256, 4));
        assert_eq!(recombination_full_adders(4, 8), full_adders(4, 8));
        // wider weights cost more in both families' trees
        assert!(accumulation_full_adders(256, 8) > accumulation_full_adders(256, 4));
        assert!(recombination_full_adders(8, 8) > recombination_full_adders(4, 8));
    }

    #[test]
    fn degenerate_inputs() {
        assert_eq!(full_adders(0, 8), 0.0);
        assert_eq!(full_adders(1, 8), 0.0);
        assert_eq!(depth(1), 0);
    }

    #[test]
    fn non_power_of_two_rounds_up() {
        assert_eq!(full_adders(48, 4), full_adders(64, 4));
        assert_eq!(depth(48), 6);
    }

    #[test]
    fn output_width_growth() {
        assert_eq!(output_bits(256, 4), 12);
        assert_eq!(output_bits(2, 8), 9);
    }
}
