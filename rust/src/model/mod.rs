//! The unified analytical cost model for AIMC and DIMC (paper §IV).
//!
//! * [`tech`] — technology-dependent parameter extraction (Fig. 6).
//! * [`energy`] — the datapath energy model (Eqs. 1–11).
//! * [`adc`] / [`dac`] — converter sub-models (Murmann k1/k2; k3).
//! * [`adder_tree`] — digital accumulation cost (Eqs. 9–10).
//! * [`area`] — cell + periphery area (Fig. 4 density axis).
//! * [`latency`] — cycle time and peak throughput.
//! * [`validation`] — model-vs-reported comparison (Fig. 5).
//!
//! Every equation, the constants behind it, the precision-scaling rules
//! ([`adc::requantized_resolution`], [`dac::resolution_for`], the
//! [`adder_tree`] width contract) and the mapping from paper figures to
//! this repo's benches are written down in `docs/COST_MODEL.md` — treat
//! that file as the model's contract: sweep caches key on these
//! semantics, so a change here is a persistent-cache schema change
//! ([`crate::sweep::SWEEP_CACHE_VERSION`]).

pub mod adc;
pub mod adder_tree;
pub mod area;
pub mod dac;
pub mod energy;
pub mod latency;
pub mod tech;
pub mod validation;

pub use energy::{
    macro_energy, peak_energy_per_mac_fj, peak_tops_per_watt, EnergyBreakdown, MacroOpCounts,
};
pub use latency::{cycle_ns, peak_tops, peak_tops_per_mm2};
pub use tech::TechParams;
pub use validation::{validate_design, ValidationPoint, ValidationStats};
