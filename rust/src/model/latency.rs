//! Latency / throughput model.
//!
//! Cycle time per family, calibrated on the surveyed designs' reported
//! clock rates: AIMC MVM cycles are paced by the analog settle + ADC
//! (~5 ns at 28 nm), DIMC by the adder-tree critical path (~1 ns at
//! 28 nm for D2 = 256, shorter for smaller trees), both scaling roughly
//! linearly with the node.

use crate::arch::{ImcFamily, ImcMacro};

use super::adder_tree;
use super::area::macro_area_mm2;

/// Macro compute-cycle time (ns).
pub fn cycle_ns(m: &ImcMacro) -> f64 {
    let node_scale = m.tech_nm / 28.0;
    // voltage derating: delay grows as V drops below nominal 0.9 V
    let v_scale = (0.9 / m.vdd).max(0.6);
    match m.family {
        ImcFamily::Aimc => 5.0 * node_scale * v_scale,
        ImcFamily::Dimc => {
            // tree depth paces the clock; ~0.125 ns per stage at 28 nm
            let depth = adder_tree::depth(m.d2()).max(4) as f64;
            0.125 * depth * node_scale * v_scale
        }
    }
}

/// Peak throughput of one macro in TOP/s (2 ops per MAC, full precision:
/// one MVM takes `cycles_per_mvm` compute cycles).
pub fn peak_tops(m: &ImcMacro) -> f64 {
    let macs_per_ns = m.macs_per_mvm() as f64 / (m.cycles_per_mvm() as f64 * cycle_ns(m));
    2.0 * macs_per_ns * 1e-3 // MAC/ns → TOP/s
}

/// Peak computational density in TOP/s/mm² (the Fig. 4 x-axis).
pub fn peak_tops_per_mm2(m: &ImcMacro) -> f64 {
    peak_tops(m) / macro_area_mm2(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ImcFamily;

    fn aimc() -> ImcMacro {
        ImcMacro::new("a", ImcFamily::Aimc, 1152, 256, 4, 4, 4, 8, 0.8, 28.0)
    }

    fn dimc() -> ImcMacro {
        ImcMacro::new("d", ImcFamily::Dimc, 256, 256, 4, 4, 1, 0, 0.8, 22.0)
    }

    #[test]
    fn dimc_clocks_faster_than_aimc() {
        assert!(cycle_ns(&dimc()) < cycle_ns(&aimc()));
    }

    #[test]
    fn smaller_node_is_faster() {
        let mut d5 = dimc();
        d5.tech_nm = 5.0;
        assert!(cycle_ns(&d5) < cycle_ns(&dimc()));
    }

    #[test]
    fn low_voltage_slows_down() {
        let mut slow = dimc();
        slow.vdd = 0.6;
        assert!(cycle_ns(&slow) > cycle_ns(&dimc()));
    }

    #[test]
    fn peak_tops_accounts_for_bit_serial() {
        // DIMC 4b act bit-serial: 4 cycles per MVM
        let d = dimc();
        let macs = d.macs_per_mvm() as f64;
        let expect = 2.0 * macs / (4.0 * cycle_ns(&d)) * 1e-3;
        assert!((peak_tops(&d) - expect).abs() < 1e-12);
    }

    #[test]
    fn density_in_survey_band() {
        // Fig. 4 densities span ~0.1..400 TOP/s/mm²
        for m in [aimc(), dimc()] {
            let dens = peak_tops_per_mm2(&m);
            assert!((0.05..500.0).contains(&dens), "{}: {dens}", m.name);
        }
    }

    #[test]
    fn tall_aimc_array_beats_dimc_density_same_node() {
        // The AIMC structural density advantage (no per-cell multiplier,
        // amortized periphery) at equal node/precision.
        let mut a = aimc();
        a.tech_nm = 22.0;
        let mut d = dimc();
        d.tech_nm = 22.0;
        assert!(peak_tops_per_mm2(&a) > peak_tops_per_mm2(&d) * 0.5);
    }
}
