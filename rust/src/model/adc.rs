//! ADC energy/area sub-model (paper §IV-C.1, Eq. 8, after Murmann).

use super::tech::{K1_FJ, K2_FJ};

/// Reference node (nm) at which the Murmann survey constants hold.
pub const K1_REF_NODE_NM: f64 = 65.0;

/// Energy of one ADC conversion (fJ), Eq. 8 kernel:
/// `(k1 · res + k2 · 4^res) · V²`.
///
/// The linear term models the digital/logic part of the converter and
/// therefore scales with the technology node (the Murmann survey
/// constants are referenced to 65 nm-class designs); the exponential
/// term is the thermal-noise-limited analog part, node independent.
/// At edge-IMC resolutions (≤ 8 b) the linear term dominates.
pub fn conversion_energy_fj_at(adc_res: u32, vdd: f64, tech_nm: f64) -> f64 {
    let r = adc_res as f64;
    let k1 = K1_FJ * (tech_nm / K1_REF_NODE_NM).min(1.5);
    (k1 * r + K2_FJ * 4f64.powf(r)) * vdd * vdd
}

/// [`conversion_energy_fj_at`] at the reference node (paper's raw Eq. 8).
pub fn conversion_energy_fj(adc_res: u32, vdd: f64) -> f64 {
    conversion_energy_fj_at(adc_res, vdd, K1_REF_NODE_NM)
}

/// ADC resolution re-derived for a re-quantized operating point (the
/// precision-scaling rule documented in `docs/COST_MODEL.md`).
///
/// One conversion digitizes the bitline sum of up to D2 single-bit
/// weight cells driven by a `dac_res`-bit input slice, so the
/// full-precision requirement is `dac_res + ceil(log2 D2)` bits.
/// Published designs under-provision that requirement by a fixed
/// *slack* (they accept clipping/quantization noise); re-quantization
/// preserves the slack. With the array geometry — and hence the D2
/// term — unchanged, the resolution shifts 1:1 with the input-slice
/// width and never drops below 1 bit. Weight precision does not enter:
/// each bitline still carries single-bit weight slices, so the per-ADC
/// dynamic range is weight-width independent.
pub fn requantized_resolution(native_adc_res: u32, native_dac_res: u32, new_dac_res: u32) -> u32 {
    (native_adc_res as i64 + new_dac_res as i64 - native_dac_res as i64).max(1) as u32
}

/// ADC area (µm²). SAR-style layout: comparator + capacitive DAC whose
/// size doubles per bit, scaled quadratically with node. Calibrated so an
/// 8-bit SAR in 28 nm occupies ~2 000 µm² (representative of the compact
/// column ADCs in the surveyed macros).
pub fn area_um2(adc_res: u32, tech_nm: f64) -> f64 {
    if adc_res == 0 {
        return 0.0;
    }
    let base = 8.0; // µm² per unit cap at 28 nm
    let scale = (tech_nm / 28.0).powi(2);
    base * 2f64.powi(adc_res as i32) * scale
}

/// Conversion latency in macro clock cycles. SAR: one bit per internal
/// cycle, pipelined against the array access → `res` internal cycles
/// overlap one array cycle for `res <=` the array cycle budget; modeled
/// as 1 macro cycle (the surveyed designs pipeline conversion).
pub fn cycles_per_conversion(_adc_res: u32) -> u64 {
    1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_monotone_in_resolution() {
        let mut last = 0.0;
        for r in 1..=12 {
            let e = conversion_energy_fj(r, 0.8);
            assert!(e > last, "res {r}: {e} <= {last}");
            last = e;
        }
    }

    #[test]
    fn linear_term_dominates_at_low_res() {
        // at 8b: k1 term = 800 fJ, k2 term = 65.5 fJ
        let e = conversion_energy_fj(8, 1.0);
        assert!((e - (800.0 + 65.536)).abs() < 0.01);
    }

    #[test]
    fn exponential_term_dominates_at_high_res() {
        let e14 = conversion_energy_fj(14, 1.0);
        assert!(4f64.powi(14) * K2_FJ > K1_FJ * 14.0);
        assert!(e14 > 268_000.0);
    }

    #[test]
    fn energy_scales_with_vdd_squared() {
        let a = conversion_energy_fj(8, 1.0);
        let b = conversion_energy_fj(8, 0.5);
        assert!((a / b - 4.0).abs() < 1e-9);
    }

    #[test]
    fn requantized_resolution_shifts_with_slice_width() {
        // narrower input slices shed exactly their dynamic-range bits
        assert_eq!(requantized_resolution(8, 4, 2), 6);
        // unchanged slice width: identity
        assert_eq!(requantized_resolution(8, 4, 4), 8);
        // never below 1 bit
        assert_eq!(requantized_resolution(1, 4, 1), 1);
        // wider slices (hypothetical) add range bits
        assert_eq!(requantized_resolution(5, 1, 2), 6);
    }

    #[test]
    fn area_calibration_point() {
        let a = area_um2(8, 28.0);
        assert!((a - 2048.0).abs() < 1.0);
        assert_eq!(area_um2(0, 28.0), 0.0);
        // smaller node -> smaller ADC
        assert!(area_um2(8, 7.0) < a);
    }
}
