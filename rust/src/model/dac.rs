//! DAC energy/area sub-model (paper §IV-D, Eq. 11).

use super::tech::K3_FJ;

/// Energy of one DAC conversion step (fJ): `k3 · DAC_res · V²`.
/// One conversion drives one wordline with one activation slice.
/// A 1-bit "DAC" is just the wordline driver — its energy is already
/// accounted for in `E_WL`, so it costs nothing here.
pub fn conversion_energy_fj(dac_res: u32, vdd: f64) -> f64 {
    if dac_res <= 1 {
        return 0.0;
    }
    K3_FJ * dac_res as f64 * vdd * vdd
}

/// DAC (input-driver) resolution at a re-quantized activation width:
/// the slice width is fixed by the hardware, but can never exceed the
/// activation precision it drives — a 4-bit DAC fed 2-bit activations
/// runs as a 2-bit DAC (precision-scaling rule, `docs/COST_MODEL.md`).
pub fn resolution_for(native_dac_res: u32, act_bits: u32) -> u32 {
    native_dac_res.min(act_bits).max(1)
}

/// Bit-serial DAC conversion cycles per full-precision activation,
/// `ceil(B_a / DAC_res)` — the `CC_BS` count per activation. Mirrors
/// [`crate::arch::ImcMacro::n_slices`], which evaluates the same rule on
/// the macro's own fields; activations wider than the slice pay extra
/// cycles rather than extra converter resolution.
pub fn cycles_per_activation(act_bits: u32, dac_res: u32) -> u32 {
    act_bits.div_ceil(dac_res.max(1))
}

/// DAC area (µm²): resistor/current-steering ladder, linear in
/// resolution, quadratic node scaling. Calibrated to ~35 µm² for a 4-bit
/// row DAC at 28 nm (row-pitch-matched layouts in the surveyed designs).
pub fn area_um2(dac_res: u32, tech_nm: f64) -> f64 {
    if dac_res <= 1 {
        // 1-bit "DAC" is just the wordline driver, counted with the array.
        return 0.0;
    }
    8.75 * dac_res as f64 * (tech_nm / 28.0).powi(2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_linear_in_resolution() {
        let e2 = conversion_energy_fj(2, 0.8);
        let e4 = conversion_energy_fj(4, 0.8);
        assert!((e4 / e2 - 2.0).abs() < 1e-12);
        let e8 = conversion_energy_fj(8, 0.8);
        assert!((e8 / e4 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn paper_constant() {
        // k3 = 44 fJ at V = 1 per resolution step (res = 2 -> 88 fJ)
        assert!((conversion_energy_fj(2, 1.0) - 88.0).abs() < 1e-12);
        // 1-bit input drive is a wordline driver, not a DAC
        assert_eq!(conversion_energy_fj(1, 1.0), 0.0);
    }

    #[test]
    fn requantized_resolution_clamps_to_activation_width() {
        assert_eq!(resolution_for(4, 2), 2);
        assert_eq!(resolution_for(4, 8), 4);
        assert_eq!(resolution_for(1, 8), 1);
        assert_eq!(resolution_for(2, 1), 1);
    }

    #[test]
    fn slice_count_matches_macro_rule() {
        use crate::arch::{ImcFamily, ImcMacro};
        assert_eq!(cycles_per_activation(8, 4), 2);
        assert_eq!(cycles_per_activation(8, 3), 3);
        assert_eq!(cycles_per_activation(4, 4), 1);
        let m = ImcMacro::new("d", ImcFamily::Dimc, 64, 256, 8, 8, 2, 0, 0.8, 22.0);
        assert_eq!(cycles_per_activation(m.act_bits, m.dac_res), m.n_slices());
    }

    #[test]
    fn one_bit_driver_has_no_dac_area() {
        assert_eq!(area_um2(1, 28.0), 0.0);
        assert!(area_um2(4, 28.0) > 0.0);
    }
}
