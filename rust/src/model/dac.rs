//! DAC energy/area sub-model (paper §IV-D, Eq. 11).

use super::tech::K3_FJ;

/// Energy of one DAC conversion step (fJ): `k3 · DAC_res · V²`.
/// One conversion drives one wordline with one activation slice.
/// A 1-bit "DAC" is just the wordline driver — its energy is already
/// accounted for in `E_WL`, so it costs nothing here.
pub fn conversion_energy_fj(dac_res: u32, vdd: f64) -> f64 {
    if dac_res <= 1 {
        return 0.0;
    }
    K3_FJ * dac_res as f64 * vdd * vdd
}

/// DAC area (µm²): resistor/current-steering ladder, linear in
/// resolution, quadratic node scaling. Calibrated to ~35 µm² for a 4-bit
/// row DAC at 28 nm (row-pitch-matched layouts in the surveyed designs).
pub fn area_um2(dac_res: u32, tech_nm: f64) -> f64 {
    if dac_res <= 1 {
        // 1-bit "DAC" is just the wordline driver, counted with the array.
        return 0.0;
    }
    8.75 * dac_res as f64 * (tech_nm / 28.0).powi(2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_linear_in_resolution() {
        let e2 = conversion_energy_fj(2, 0.8);
        let e4 = conversion_energy_fj(4, 0.8);
        assert!((e4 / e2 - 2.0).abs() < 1e-12);
        let e8 = conversion_energy_fj(8, 0.8);
        assert!((e8 / e4 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn paper_constant() {
        // k3 = 44 fJ at V = 1 per resolution step (res = 2 -> 88 fJ)
        assert!((conversion_energy_fj(2, 1.0) - 88.0).abs() < 1e-12);
        // 1-bit input drive is a wordline driver, not a DAC
        assert_eq!(conversion_energy_fj(1, 1.0), 0.0);
    }

    #[test]
    fn one_bit_driver_has_no_dac_area() {
        assert_eq!(area_um2(1, 28.0), 0.0);
        assert!(area_um2(4, 28.0) > 0.0);
    }
}
