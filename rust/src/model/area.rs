//! Area model: SRAM array + cell-level compute overhead + peripherals.
//!
//! Used to derive the computational-density axis (TOP/s/mm²) of the
//! survey (Fig. 4). Calibrated on foundry-reported 6T cell sizes
//! (~150 F²) and the relative cell overheads the surveyed papers report:
//! AIMC cells with local capacitors ≈ 1.8× a 6T cell, DIMC cells with the
//! fused NAND multiplier ≈ 2.2×, plus per-column adder-tree /
//! shift-accumulate logic.

use crate::arch::{ImcFamily, ImcMacro};

use super::adc;
use super::adder_tree;
use super::dac;

/// 6T SRAM cell size in F² (feature-size-squared units).
pub const SRAM_CELL_F2: f64 = 150.0;
/// AIMC compute cell overhead vs plain 6T (local cap / switches).
pub const AIMC_CELL_FACTOR: f64 = 1.8;
/// DIMC compute cell overhead vs plain 6T (NAND multiplier per cell).
pub const DIMC_CELL_FACTOR: f64 = 2.2;
/// Area per logic gate in F² (std-cell NAND2 footprint incl. routing).
pub const GATE_F2: f64 = 280.0;

fn f2_to_um2(f2: f64, tech_nm: f64) -> f64 {
    // 1 F² = (tech_nm * 1e-3 µm)²
    f2 * (tech_nm * 1e-3) * (tech_nm * 1e-3)
}

/// Array (cell matrix) area in µm².
pub fn array_area_um2(m: &ImcMacro) -> f64 {
    let factor = match m.family {
        ImcFamily::Aimc => AIMC_CELL_FACTOR,
        ImcFamily::Dimc => DIMC_CELL_FACTOR,
    };
    f2_to_um2(SRAM_CELL_F2 * factor, m.tech_nm) * (m.rows * m.cols) as f64
}

/// Peripheral area in µm²: converters + digital accumulation.
pub fn periphery_area_um2(m: &ImcMacro) -> f64 {
    match m.family {
        ImcFamily::Aimc => {
            let n_adc = (m.d1() as u32 * m.weight_bits / m.cols_per_adc) as f64;
            let n_dac = m.rows as f64;
            let adc_a = adc::area_um2(m.adc_res, m.tech_nm) * n_adc;
            let dac_a = dac::area_um2(m.dac_res, m.tech_nm) * n_dac;
            // shift-add recombination tree per operand column
            let f = adder_tree::recombination_full_adders(m.weight_bits, m.adc_res);
            let tree_a = f2_to_um2(GATE_F2, m.tech_nm) * f * super::tech::G_FA * m.d1() as f64;
            adc_a + dac_a + tree_a
        }
        ImcFamily::Dimc => {
            let f = adder_tree::accumulation_full_adders(m.d2(), m.weight_bits);
            f2_to_um2(GATE_F2, m.tech_nm) * f * super::tech::G_FA * m.d1() as f64
        }
    }
}

/// Total macro area in mm².
pub fn macro_area_mm2(m: &ImcMacro) -> f64 {
    (array_area_um2(m) + periphery_area_um2(m)) * 1e-6
}

/// Fraction of macro area spent on peripherals (the AIMC amortization
/// argument of §II-B: a large array amortizes its converters).
pub fn periphery_fraction(m: &ImcMacro) -> f64 {
    periphery_area_um2(m) / (array_area_um2(m) + periphery_area_um2(m))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ImcFamily;

    fn aimc(rows: usize, cols: usize) -> ImcMacro {
        ImcMacro::new("a", ImcFamily::Aimc, rows, cols, 4, 4, 4, 8, 0.8, 28.0)
    }

    fn dimc(rows: usize, cols: usize) -> ImcMacro {
        ImcMacro::new("d", ImcFamily::Dimc, rows, cols, 4, 4, 1, 0, 0.8, 22.0)
    }

    #[test]
    fn cell_area_calibration() {
        // 28 nm 6T ≈ 150 * (0.028)² ≈ 0.1176 µm²; AIMC cell 1.8x
        let m = aimc(1, 1);
        assert!((array_area_um2(&m) - 0.2117).abs() < 0.01);
    }

    #[test]
    fn large_array_amortizes_peripherals() {
        let small = aimc(64, 256);
        let large = aimc(1152, 256);
        assert!(periphery_fraction(&large) < periphery_fraction(&small));
    }

    #[test]
    fn dimc_has_no_converter_area() {
        let d = dimc(256, 256);
        // periphery = adder trees only; grows with D2
        let d_small = dimc(64, 256);
        assert!(periphery_area_um2(&d) > periphery_area_um2(&d_small));
        let a = aimc(256, 256);
        assert!(periphery_area_um2(&a) > periphery_area_um2(&dimc_same_node(&a)));
    }

    fn dimc_same_node(a: &ImcMacro) -> ImcMacro {
        let mut d = a.clone();
        d.family = ImcFamily::Dimc;
        d.adc_res = 0;
        d.dac_res = 1;
        d
    }

    #[test]
    fn area_scales_quadratically_with_node() {
        let m28 = aimc(256, 256);
        let mut m7 = m28.clone();
        m7.tech_nm = 7.0;
        let ratio = array_area_um2(&m28) / array_area_um2(&m7);
        assert!((ratio - (28.0f64 / 7.0).powi(2)).abs() < 1e-9);
    }

    #[test]
    fn macro_area_plausible() {
        // 1152x256 AIMC in 28nm: a fraction of a mm²
        let a = macro_area_mm2(&aimc(1152, 256));
        assert!((0.05..2.0).contains(&a), "area {a} mm2");
    }
}
