//! Model validation against reported silicon numbers (paper §V, Fig. 5).
//!
//! For every surveyed design we evaluate the unified model at the chip's
//! architectural parameters and compare against the publication's
//! reported peak energy efficiency. The paper finds mismatches within
//! ~15 % for most designs, with known outliers (unmodeled digital
//! overheads, inefficient ADCs ~4×, leakage at low voltage).

use crate::arch::ImcMacro;

use super::energy::peak_tops_per_watt;
use super::latency::peak_tops_per_mm2;
use super::tech::TechParams;

/// One model-vs-reported comparison point.
#[derive(Debug, Clone)]
pub struct ValidationPoint {
    /// Design name (chip @ operating point).
    pub name: String,
    /// Family tag (`AIMC`/`DIMC`).
    pub family: String,
    /// Technology node (nm).
    pub tech_nm: f64,
    /// Reported peak efficiency (TOP/s/W).
    pub reported_tops_w: f64,
    /// Model-predicted peak efficiency (TOP/s/W).
    pub modeled_tops_w: f64,
    /// Reported computational density, when published.
    pub reported_tops_mm2: Option<f64>,
    /// Model-predicted computational density (TOP/s/mm²).
    pub modeled_tops_mm2: f64,
    /// |modeled − reported| / reported for energy efficiency.
    pub mismatch: f64,
    /// Designs the paper itself flags as >15 % (unmodeled overheads).
    pub known_outlier: bool,
}

/// Validate one design: run the model at the design's parameters.
pub fn validate_design(
    m: &ImcMacro,
    reported_tops_w: f64,
    reported_tops_mm2: Option<f64>,
    input_sparsity: f64,
    known_outlier: bool,
) -> ValidationPoint {
    let tech = TechParams::for_node(m.tech_nm);
    let modeled_tops_w = peak_tops_per_watt(m, &tech, input_sparsity);
    let modeled_tops_mm2 = peak_tops_per_mm2(m);
    let mismatch = (modeled_tops_w - reported_tops_w).abs() / reported_tops_w;
    ValidationPoint {
        name: m.name.clone(),
        family: m.family.as_str().to_string(),
        tech_nm: m.tech_nm,
        reported_tops_w,
        modeled_tops_w,
        reported_tops_mm2,
        modeled_tops_mm2,
        mismatch,
        known_outlier,
    }
}

/// Aggregate mismatch statistics over a set of validation points.
#[derive(Debug, Clone)]
pub struct ValidationStats {
    /// Points compared.
    pub n: usize,
    /// Points within the paper's 15 % band.
    pub n_within_15pct: usize,
    /// Points the paper flags as known outliers.
    pub n_known_outliers: usize,
    /// Mean relative mismatch.
    pub mean_mismatch: f64,
    /// Median relative mismatch.
    pub median_mismatch: f64,
    /// Worst relative mismatch.
    pub max_mismatch: f64,
}

impl ValidationStats {
    /// Aggregate a set of validation points.
    pub fn from_points(points: &[ValidationPoint]) -> Self {
        let mut mismatches: Vec<f64> = points.iter().map(|p| p.mismatch).collect();
        mismatches.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = points.len();
        let mean = if n == 0 {
            0.0
        } else {
            mismatches.iter().sum::<f64>() / n as f64
        };
        let median = if n == 0 {
            0.0
        } else {
            mismatches[n / 2]
        };
        ValidationStats {
            n,
            n_within_15pct: points.iter().filter(|p| p.mismatch <= 0.15).count(),
            n_known_outliers: points.iter().filter(|p| p.known_outlier).count(),
            mean_mismatch: mean,
            median_mismatch: median,
            max_mismatch: mismatches.last().copied().unwrap_or(0.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ImcFamily;

    #[test]
    fn perfect_report_has_zero_mismatch() {
        let m = ImcMacro::new("x", ImcFamily::Dimc, 64, 256, 4, 4, 1, 0, 0.8, 22.0);
        let tech = TechParams::for_node(m.tech_nm);
        let exact = peak_tops_per_watt(&m, &tech, 0.5);
        let p = validate_design(&m, exact, None, 0.5, false);
        assert!(p.mismatch < 1e-12);
    }

    #[test]
    fn stats_aggregate() {
        let m = ImcMacro::new("x", ImcFamily::Dimc, 64, 256, 4, 4, 1, 0, 0.8, 22.0);
        let tech = TechParams::for_node(m.tech_nm);
        let exact = peak_tops_per_watt(&m, &tech, 0.5);
        let pts = vec![
            validate_design(&m, exact, None, 0.5, false),
            validate_design(&m, exact * 2.0, None, 0.5, true), // 50 % off
        ];
        let s = ValidationStats::from_points(&pts);
        assert_eq!(s.n, 2);
        assert_eq!(s.n_within_15pct, 1);
        assert_eq!(s.n_known_outliers, 1);
        assert!(s.max_mismatch > 0.4);
    }
}
