//! `imcsim` — the launcher.
//!
//! Subcommands regenerate every table/figure of the paper, run the DSE,
//! validate the model against the silicon survey, and serve functional
//! inference through the AOT-compiled macro artifacts.

use std::path::PathBuf;
use std::time::Instant;

use imcsim::arch::{load_system, table2_systems, ImcFamily};
use imcsim::dse::{search_network_with, DseOptions, ExhaustiveSearch, Objective};
use imcsim::mapping::TemporalPolicy;
use imcsim::report::{
    eng, fig1_text, fig4_text, fig5_text, fig6_text, fig7_results, fig7_text, fmt_sqnr,
    fmt_sqnr_trials, parse_sweep_csv, surface_csv, sweep_csv, sweep_text, table2_text, Table,
};
use imcsim::runtime::{default_artifacts_dir, load_manifest};
use imcsim::serve::{
    bursty_arrivals, poisson_arrivals, replay_outcome_per_stage, rung_gap_ps, simulate,
    simulate_per_stage, slo_throughput, slo_throughput_with, DispatchPolicy, NetworkServeCost,
    Schedule, ServeConfig, StageTable, TenantSpec, TraceKind,
};
use imcsim::sim::NoiseSpec;
use imcsim::sweep::{
    load_cache_into, merge_summaries, run_sweep, run_sweep_with_cache, save_cache, CacheStats,
    CostCache, PrecisionPoint, SweepGrid, SweepOptions, SweepSummary,
};
use imcsim::util::cli::{
    parse_list, parse_serve_config, parse_tenants, parse_threads, reject_unknown, Args, SweepAxes,
};
use imcsim::util::pool::parallel_map_with;

const HELP: &str = "\
imcsim — benchmarking & modeling of analog/digital SRAM in-memory computing
(reproduction of Houshmand, Sun, Verhelst 2023)

USAGE: imcsim <command> [options]

Paper artifacts:
  fig1                 operator breakdown of the tinyMLPerf models
  fig4                 survey scatter: TOP/s/W vs TOP/s/mm2
  fig5 [--family aimc|dimc]
                       model validation vs reported silicon
  fig6                 technology parameter extraction (C_inv, k3)
  fig7 [--csv FILE]    case study: 4 systems x 4 tinyMLPerf networks
  table2               case-study architecture table
  validate             aggregate model-vs-silicon mismatch statistics

Exploration & serving:
  dse --network <ae|resnet8|dscnn|mobilenet> [--system NAME] [--config FILE]
      [--objective energy|latency|edp|accuracy] [--policy ws|os|is]
      [--sparsity F[,F...]] [--noise S[,S...]] [--threads N]
                       per-layer optimal mappings for one network, with
                       the bit-true simulator's per-layer SQNR (the
                       accuracy objective is mapping-invariant and
                       reports the energy-optimal mapping); --noise
                       layers the seeded analog-noise model onto the
                       AIMC datapath and reports trial mean/σ SQNR.
                       --sparsity and --noise take the same comma-list
                       forms `sweep` does (off|typical|worst and/or
                       A_CAP:T_FACTOR:OFFSET_LSB triples) and report
                       each combination in turn
  sweep [--shards N] [--shard-index K] [--cells N[,N...]]
      [--precision P[,P...]] [--sparsity F[,F...]]
      [--noise S[,S...]] [--serve-requests N] [--serve-slo-ms F]
      [--serve-seed S] [--cache-file FILE] [--csv FILE]
      [--surface-csv FILE] [--threads N]
                       full-grid DSE sweep: every surveyed design (per
                       SRAM-cell budget) x every tinyMLPerf network x
                       every precision point x every sparsity level x
                       every noise spec x every objective, streamed
                       through the bound-pruned mapping search and a
                       memoized cost+accuracy cache; prints
                       per-(network, precision) cost Pareto frontiers,
                       per-network accuracy-vs-energy frontiers
                       (bit-true simulated SQNR / max-abs error / ADC
                       clip rate columns, plus trial mean/σ SQNR under
                       noise), the 3-objective (energy, latency, SQNR)
                       Pareto surface, and evaluated/pruned candidate
                       counts.
                       --precision takes WxA weight-x-activation pairs
                       (e.g. 2x8,4x8,8x8) and/or 'native'; each design
                       is re-quantized to each point (converter
                       resolutions re-derived, unrealizable pairs
                       skipped). --noise takes off|typical|worst and/or
                       explicit A_CAP:T_FACTOR:OFFSET_LSB sigmas (e.g.
                       0.02:1:0.25); DIMC designs are unaffected by
                       every spec. --shards/--shard-index split the
                       grid deterministically across CI jobs or
                       machines; --cache-file persists the cost cache
                       across runs (version-tagged; stale schemas are
                       rejected); --surface-csv dumps the 3-objective
                       Pareto surface. Every grid point also carries
                       the serving columns (canonical-trace req/s
                       under SLO plus the best (schedule, batch)
                       config found by the pruned serving search),
                       memoized so identical replays across
                       objectives and noise corners run once;
                       --serve-requests / --serve-slo-ms /
                       --serve-seed retarget the serving trace
                       (defaults 512 / 2 / 42 keep CSVs bit-identical
                       to earlier releases).
  sweepmerge [--csv FILE] [--surface-csv FILE]
      [--serve-requests N] [--serve-slo-ms F] [--serve-seed S]
      [--threads N] SHARD.csv [SHARD.csv ...]
                       merge shard CSVs (written by `sweep --csv`) back
                       into the full-grid summary, Pareto frontiers and
                       3-objective surface
  archsweep --network <ae|resnet8|dscnn|mobilenet> [--family aimc|dimc]
      [--cells N] [--threads N]
                       geometry sweep of one network at equal SRAM
                       budget; prints the (energy, latency) Pareto front
  serve [--design NAME[,NAME...]] [--network <ae|resnet8|dscnn|mobilenet>[,...]]
      [--schedule serialized|layer-pipelined[,...]] [--batch N[,N...]]
      [--util F[,F...]] [--trace poisson|bursty] [--requests N]
      [--seed S] [--burst-period-us F] [--burst-duty PCT]
      [--slo-ms F] [--batching global|per-stage] [--csv FILE] [--threads N]
                       single-tenant serving simulation on the calibrated
                       cost model (std-only): replay a seeded synthetic
                       arrival trace against each (design, network,
                       schedule, max-batch, utilization) cell with
                       greedy FIFO batching; reports p50/p99/mean/max
                       latency, energy and weight-reload energy per
                       request, sustained req/s, and SLO-constrained
                       req/s under the --slo-ms p99 target. --util is
                       the offered load as a fraction of the schedule's
                       bottleneck capacity; --batching per-stage rebatches
                       at every pipeline stage (heterogeneous per-layer
                       batches; layer-pipelined schedules only); same
                       --seed => byte-identical CSV for every --threads
                       count
  serve --tenants NET[:key=val]...,NET[:key=val]... [--design NAME[,NAME...]]
      [--schedule serialized|layer-pipelined[,...]]
      [--policy fifo|priority|drr[,...]] [--batch N] [--requests N]
      [--seed S] [--csv FILE] [--threads N]
                       multi-tenant serving: all listed tenants time-share
                       each design under one dispatch policy, with weight
                       swap stalls/energy charged on tenant switch-ins
                       (from the cost model's own weight-load terms),
                       per-tenant SLO admission control (a tenant whose
                       zero-queueing bound busts its SLO is rejected up
                       front), and per-tenant latency/energy/goodput
                       rows plus an aggregate '*' row per cell. Tenant
                       keys: slo-ms, prio, share, util,
                       trace=poisson|bursty|closed, period-us, duty,
                       clients, think-us, name (see docs/COST_MODEL.md
                       section 13). Same --seed => byte-identical CSV for
                       every --threads count
  serve --sweep [--design NAME[,NAME...]] [--network <ae|resnet8|dscnn|mobilenet>[,...]]
      [--requests N] [--seed S] [--slo-ms F] [--csv FILE] [--threads N]
                       serving-configuration search: for each (design,
                       network) pair search schedule x batch cap
                       (layer-pipelined/serialized x 8,4,2,1) for the
                       best SLO-constrained req/s, with admissible
                       incumbent pruning and memoized replays; reports
                       the canonical-trace point beside the winner and
                       the replay-reduction statistics. Byte-identical
                       CSV for every --threads count
  artifacts            show the AOT artifact manifest

Options:
  --artifacts DIR      artifact directory (default: ./artifacts or $IMCSIM_ARTIFACTS)
  --threads N          worker threads for dse/sweep/sweepmerge/archsweep
                       (default: $IMCSIM_THREADS, else the CPU count; the
                       flag wins over the environment variable). Results
                       are bit-identical for every thread count.
";

fn main() {
    let args = Args::from_env();
    let code = match args.subcommand.as_deref() {
        Some("fig1") => {
            println!("{}", fig1_text());
            0
        }
        Some("fig4") => {
            println!("{}", fig4_text());
            0
        }
        Some("fig5") => {
            if let Err(e) = reject_unknown(&args, "fig5", &["family"]) {
                eprintln!("{e}");
                std::process::exit(2);
            }
            let family = match args.opt("family") {
                Some("aimc") => Some(ImcFamily::Aimc),
                Some("dimc") => Some(ImcFamily::Dimc),
                None => None,
                Some(other) => {
                    eprintln!("unknown family '{other}'");
                    std::process::exit(2);
                }
            };
            println!("{}", fig5_text(family));
            0
        }
        Some("fig6") => {
            println!("{}", fig6_text());
            0
        }
        Some("fig7") => cmd_fig7(&args),
        Some("table2") => {
            println!("{}", table2_text());
            0
        }
        Some("validate") => cmd_validate(),
        Some("dse") => cmd_dse(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("sweepmerge") => cmd_sweepmerge(&args),
        Some("archsweep") => cmd_archsweep(&args),
        Some("serve") => cmd_serve(&args),
        Some("artifacts") => cmd_artifacts(&args),
        Some("help") | None => {
            println!("{HELP}");
            0
        }
        Some(other) => {
            eprintln!("unknown command '{other}'\n\n{HELP}");
            2
        }
    };
    std::process::exit(code);
}

fn cmd_fig7(args: &Args) -> i32 {
    if let Err(e) = reject_unknown(args, "fig7", &["csv"]) {
        eprintln!("{e}");
        return 2;
    }
    let t0 = Instant::now();
    let results = fig7_results();
    println!("{}", fig7_text(&results));
    println!("(evaluated in {:.2}s)", t0.elapsed().as_secs_f64());
    if let Some(path) = args.opt("csv") {
        let mut t = Table::new(&["network", "system", "total_fj", "time_ns", "tops_w", "util"]);
        for r in &results {
            t.row(vec![
                r.network.clone(),
                r.system.clone(),
                r.total_energy_fj().to_string(),
                r.total_time_ns().to_string(),
                r.effective_tops_per_watt().to_string(),
                r.mean_utilization().to_string(),
            ]);
        }
        if let Err(e) = std::fs::write(path, t.to_csv()) {
            eprintln!("cannot write csv: {e}");
            return 1;
        }
        println!("wrote {path}");
    }
    0
}

fn cmd_validate() -> i32 {
    for (family, label) in [
        (Some(ImcFamily::Aimc), "AIMC (Fig. 5a)"),
        (Some(ImcFamily::Dimc), "DIMC (Fig. 5b)"),
        (None, "overall"),
    ] {
        let s = imcsim::db::validation_stats(family);
        println!(
            "{label:16} n={} within15%={} median={:.1}% mean={:.1}% max={:.1}%",
            s.n,
            s.n_within_15pct,
            s.median_mismatch * 100.0,
            s.mean_mismatch * 100.0,
            s.max_mismatch * 100.0
        );
    }
    0
}

fn cmd_dse(args: &Args) -> i32 {
    // Reject unknown options rather than silently falling back to
    // defaults — a misspelled --noise must not quietly report
    // noise-free numbers as if they were the requested corner (the
    // same guard `sweep` has for its axes).
    if let Err(e) = reject_unknown(
        args,
        "dse",
        &["network", "system", "config", "objective", "policy", "sparsity", "noise", "threads"],
    ) {
        eprintln!("{e}");
        return 2;
    }
    let threads = match parse_threads(args) {
        Ok(n) => n,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let net = match args.opt("network") {
        Some("ae") | Some("autoencoder") => imcsim::workload::deep_autoencoder(),
        Some("resnet8") => imcsim::workload::resnet8(),
        Some("dscnn") | Some("ds-cnn") => imcsim::workload::ds_cnn(),
        Some("mobilenet") => imcsim::workload::mobilenet_v1(),
        other => {
            eprintln!("--network must be ae|resnet8|dscnn|mobilenet (got {other:?})");
            return 2;
        }
    };
    let systems = if let Some(cfg) = args.opt("config") {
        match load_system(&PathBuf::from(cfg)) {
            Ok(s) => vec![s],
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    } else {
        let all = table2_systems();
        match args.opt("system") {
            Some(name) => match all.into_iter().find(|s| s.name == name) {
                Some(s) => vec![s],
                None => {
                    eprintln!("unknown system '{name}'");
                    return 2;
                }
            },
            None => all,
        }
    };
    let objective: Objective = match args.opt_or("objective", "energy").parse() {
        Ok(o) => o,
        Err(e) => {
            eprintln!("{e} (expected energy|latency|edp|accuracy)");
            return 2;
        }
    };
    let policy = match args.opt("policy") {
        Some("ws") => Some(TemporalPolicy::WeightStationary),
        Some("os") => Some(TemporalPolicy::OutputStationary),
        Some("is") => Some(TemporalPolicy::InputStationary),
        None => None,
        Some(other) => {
            eprintln!("unknown policy '{other}'");
            return 2;
        }
    };
    // the comma-list sparsity/noise axes, parsed exactly as `sweep`
    // parses them (dse ignores the cells/precision axes, which its
    // accepted-option list already rejects)
    let axes = match SweepAxes::from_args(args) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let multi = axes.sparsities.len() * axes.noises.len() > 1;
    for sys in &systems {
        for &sparsity in &axes.sparsities {
            for &noise in &axes.noises {
                let opts = DseOptions {
                    objective,
                    input_sparsity: sparsity,
                    policy,
                    noise,
                };
                let tag = if multi {
                    format!(" @ sparsity {sparsity}, noise {noise}")
                } else {
                    String::new()
                };
                dse_report(&net, sys, &opts, &tag, threads);
            }
        }
    }
    0
}

/// Search one (system, sparsity, noise) combination and print the
/// per-layer mapping table, totals, accuracy and search statistics —
/// the body of each `dse` axis combination.
fn dse_report(
    net: &imcsim::workload::Network,
    sys: &imcsim::arch::ImcSystem,
    opts: &DseOptions,
    tag: &str,
    threads: usize,
) {
    let noise = opts.noise;
    let t0 = Instant::now();
    let r = search_network_with(net, sys, opts, &ExhaustiveSearch, threads);
    println!(
        "\n=== {} on {}{tag} ({} layers, {:.1} ms search) ===",
        r.network,
        r.system,
        r.layers.len(),
        t0.elapsed().as_secs_f64() * 1e3
    );
    let mut t = Table::new(&[
        "layer", "type", "MACs", "policy", "macros", "util", "E_macro[nJ]", "E_mem[nJ]",
        "t[us]", "TOP/s/W", "SQNR[dB]",
    ]);
    for l in &r.layers {
        let b = &l.best;
        let sqnr = fmt_sqnr(l.accuracy.sqnr_db());
        t.row(vec![
            l.layer.name.clone(),
            l.layer.ltype.to_string(),
            eng(l.layer.macs() as f64),
            b.policy.as_str().into(),
            b.tiles.active_macros.to_string(),
            format!("{:.1}%", b.utilization * 100.0),
            format!("{:.2}", b.macro_energy.total_fj() * 1e-6),
            format!("{:.2}", b.traffic.total_fj() * 1e-6),
            format!("{:.2}", b.time_ns * 1e-3),
            format!("{:.0}", b.tops_per_watt()),
            sqnr,
        ]);
    }
    println!("{}", t.render());
    let acc = r.accuracy();
    println!(
        "total: E={:.2} uJ  t={:.2} ms  eff={:.1} TOP/s/W  util={:.1}%",
        r.total_energy_fj() * 1e-9,
        r.total_time_ns() * 1e-6,
        r.effective_tops_per_watt(),
        r.mean_utilization() * 100.0
    );
    if acc.is_exact() {
        println!("accuracy: bit-exact datapath (simulated, {} outputs)", acc.outputs);
    } else {
        println!(
            "accuracy: SQNR={:.1} dB  max|err|={:.0}  ADC clip rate={:.2}% \
             (simulated, {} outputs)",
            acc.sqnr_db(),
            acc.max_abs_err,
            acc.clip_rate() * 100.0,
            acc.outputs
        );
    }
    if !matches!(noise, NoiseSpec::Off) {
        println!(
            "analog noise ({noise}): SQNR over {} seeded trials = {} dB",
            imcsim::sim::NOISE_TRIALS,
            fmt_sqnr_trials(acc.sqnr_mean_db(), acc.sqnr_std_db())
        );
    }
    let (evaluated, pruned) = r
        .layers
        .iter()
        .fold((0usize, 0usize), |(e, p), l| (e + l.evaluated, p + l.pruned));
    println!(
        "mapping search: {} candidates — {evaluated} evaluated, {pruned} pruned by bound",
        evaluated + pruned
    );
}

/// Full-grid DSE sweep: every surveyed silicon design (instantiated per
/// SRAM-cell budget) × every tinyMLPerf network × every activation
/// sparsity × every objective, evaluated through the bound-pruned
/// streaming mapping search and the memoized cost cache, aggregated
/// into per-network Pareto frontiers. `--shards N --shard-index K`
/// evaluates one deterministic slice (for CI jobs / multiple machines);
/// `--shards N` alone runs all N shards locally and merges them,
/// exercising the same merge path the distributed run uses.
/// `--cache-file` persists the cost cache so the next run starts warm.
fn cmd_sweep(args: &Args) -> i32 {
    if args.opt("network").is_some() || args.opt("family").is_some() {
        eprintln!(
            "sweep no longer takes --network/--family: it always runs the full \
             survey grid. The single-network geometry sweep is now `archsweep`."
        );
        return 2;
    }
    // Reject unknown options and valueless forms of the known ones
    // rather than silently falling back to defaults: a CI matrix job
    // with an empty or misspelled shard variable must not quietly run
    // the whole grid.
    if let Err(e) = reject_unknown(
        args,
        "sweep",
        &[
            "shards", "shard-index", "cells", "precision", "sparsity", "noise",
            "serve-requests", "serve-slo-ms", "serve-seed", "csv", "surface-csv", "cache-file",
            "threads",
        ],
    ) {
        eprintln!("{e}");
        return 2;
    }
    let serve = match parse_serve_config(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let threads = match parse_threads(args) {
        Ok(n) => n,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let shards: usize = match args.opt_parse("shards").unwrap_or(Ok(1)) {
        Ok(n) if n >= 1 => n,
        _ => {
            eprintln!("--shards must be a positive integer");
            return 2;
        }
    };
    let shard_index: Option<usize> = match args.opt_parse("shard-index") {
        None => None,
        Some(Ok(k)) if k < shards => Some(k),
        _ => {
            eprintln!("--shard-index must be an integer in 0..{shards}");
            return 2;
        }
    };
    // The four shared axes, in the same comma-list forms `dse` accepts
    let SweepAxes { cells, precisions, sparsities, noises } = match SweepAxes::from_args(args) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };

    // Per-precision realizability report (the db-level validity filter;
    // same ImcMacro::requantized core the grid's per-group skip uses)
    let n_survey = imcsim::db::survey().len();
    for point in &precisions {
        if let PrecisionPoint::Fixed(p) = point {
            let realizable = imcsim::db::survey_macros_at(Some(*p)).len();
            if realizable < n_survey {
                println!(
                    "precision {p}: {realizable}/{n_survey} survey designs can realize it \
                     (the rest are skipped)"
                );
            }
        }
    }

    let grid = SweepGrid::survey_tinymlperf_full(&cells, &precisions, &sparsities, &noises);
    println!(
        "grid: {} designs ({} cell budgets) x {} networks x {} precisions x {} sparsities \
         x {} noise specs x {} objectives = {} tasks (unrealizable design-precision pairs \
         are skipped)",
        grid.systems.len(),
        cells.len(),
        grid.networks.len(),
        grid.precisions.len(),
        grid.sparsities.len(),
        grid.noises.len(),
        grid.objectives.len(),
        grid.n_tasks()
    );

    let cache = CostCache::new();
    let cache_file = args.opt("cache-file").map(PathBuf::from);
    if let Some(path) = &cache_file {
        use imcsim::sweep::CacheLoadError;
        match load_cache_into(path, &cache) {
            Ok(n) => println!(
                "cost cache: warmed {n} records (searches + trial energies + serve replays) \
                 from {}",
                path.display()
            ),
            // no file yet is the normal first run, not an error
            Err(CacheLoadError::Io(e)) if e.kind() == std::io::ErrorKind::NotFound => {
                println!("cost cache: {} not found — starting cold", path.display())
            }
            // other errors name the cause explicitly (a pre-precision
            // v1 file must say *why* it was refused)
            Err(e) => println!("cost cache: starting cold — {}: {e}", path.display()),
        }
    }

    let t0 = Instant::now();
    let summary = match shard_index {
        Some(_) => {
            let opts = SweepOptions {
                shards,
                shard_index,
                threads,
                serve,
                ..Default::default()
            };
            run_sweep_with_cache(&grid, &opts, &cache)
        }
        None if shards > 1 => {
            // Without --cache-file each shard gets its own cache, like
            // the distributed CI run this path models — sharing one
            // would inflate the merged hit-rate/entry stats. A cache
            // file opts into sharing (that is its whole point).
            let parts: Vec<_> = (0..shards)
                .map(|k| {
                    let opts = SweepOptions {
                        shards,
                        shard_index: Some(k),
                        threads,
                        serve,
                        ..Default::default()
                    };
                    if cache_file.is_some() {
                        run_sweep_with_cache(&grid, &opts, &cache)
                    } else {
                        run_sweep(&grid, &opts)
                    }
                })
                .collect();
            merge_summaries(&parts)
        }
        None => {
            let opts = SweepOptions { threads, serve, ..Default::default() };
            run_sweep_with_cache(&grid, &opts, &cache)
        }
    };
    println!("{}", sweep_text(&summary));
    println!("(evaluated in {:.2}s)", t0.elapsed().as_secs_f64());
    if let Some(path) = &cache_file {
        match save_cache(&cache, path) {
            Ok(()) => {
                let s = cache.stats();
                println!(
                    "cost cache: saved {} search entries + {} trial records + {} serve \
                     entries to {}",
                    s.entries,
                    s.trial_entries,
                    s.serve_entries,
                    path.display()
                )
            }
            Err(e) => {
                eprintln!("cannot write cache file: {e}");
                return 1;
            }
        }
    }
    if let Some(path) = args.opt("csv") {
        if let Err(e) = std::fs::write(path, sweep_csv(&summary)) {
            eprintln!("cannot write csv: {e}");
            return 1;
        }
        println!("wrote {path}");
    }
    if let Some(path) = args.opt("surface-csv") {
        if let Err(e) = std::fs::write(path, surface_csv(&summary)) {
            eprintln!("cannot write surface csv: {e}");
            return 1;
        }
        println!("wrote {path}");
    }
    0
}

/// Merge shard CSVs (written by `sweep --shards N --shard-index K
/// --csv ...`) back into the full-grid summary: the CI matrix path.
/// Points are parsed losslessly, reassembled in canonical task order
/// and the per-network Pareto frontiers and the 3-objective surface
/// recomputed — bit-identical to a single-process run over the same
/// tasks.
fn cmd_sweepmerge(args: &Args) -> i32 {
    // same guard as sweep/dse: a misspelled --surface-csv must not
    // silently drop the surface artifact with exit 0
    if let Err(e) = reject_unknown(
        args,
        "sweepmerge",
        &["csv", "surface-csv", "serve-requests", "serve-slo-ms", "serve-seed", "threads"],
    ) {
        eprintln!("{e}");
        return 2;
    }
    // sweepmerge accepts the same serve knobs its shard sweeps took so
    // a CI matrix can pass one flag set to both commands; the merged
    // serving columns come from the shard CSVs, so the values are only
    // validated here, never applied.
    if let Err(e) = parse_serve_config(args) {
        eprintln!("{e}");
        return 2;
    }
    let threads = match parse_threads(args) {
        Ok(n) => n,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if args.positional.is_empty() {
        eprintln!(
            "sweepmerge needs at least one shard CSV \
             (usage: sweepmerge [--csv OUT] [--surface-csv OUT] SHARD.csv ...)"
        );
        return 2;
    }
    // Shard files parse independently, so read them on the same pool
    // the sweep itself uses; parallel_map_with keeps input order, so
    // the merged result is identical to the old serial loop's.
    let n_shards = args.positional.len();
    let parsed = parallel_map_with(&args.positional, threads, |path| {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read {path}: {e}"))?;
        let points = parse_sweep_csv(&text).map_err(|e| format!("{path}: {e}"))?;
        let max_task = points.iter().map(|p| p.task_index + 1).max().unwrap_or(0);
        Ok::<SweepSummary, String>(SweepSummary {
            shards: n_shards,
            shard_index: None,
            total_tasks: max_task,
            points,
            frontiers: Vec::new(),
            accuracy_frontiers: Vec::new(),
            surfaces: Vec::new(),
            serve_frontiers: Vec::new(),
            cache: CacheStats::default(),
            merged: false,
        })
    });
    let mut parts: Vec<SweepSummary> = Vec::new();
    for r in parsed {
        match r {
            Ok(s) => parts.push(s),
            Err(e) => {
                eprintln!("{e}");
                return 1;
            }
        }
    }
    let merged = merge_summaries(&parts);
    println!(
        "merged {} shard files -> {} grid points",
        args.positional.len(),
        merged.points.len()
    );
    println!("{}", sweep_text(&merged));
    if let Some(path) = args.opt("csv") {
        if let Err(e) = std::fs::write(path, sweep_csv(&merged)) {
            eprintln!("cannot write csv: {e}");
            return 1;
        }
        println!("wrote {path}");
    }
    if let Some(path) = args.opt("surface-csv") {
        if let Err(e) = std::fs::write(path, surface_csv(&merged)) {
            eprintln!("cannot write surface csv: {e}");
            return 1;
        }
        println!("wrote {path}");
    }
    0
}

/// Architecture sweep: enumerate macro geometries at a fixed total
/// SRAM-cell budget, evaluate the chosen network on each, and report
/// the (energy, latency) Pareto-optimal design points — the "optimal
/// design points for targeted tinyMLperf workloads" use of the model.
fn cmd_archsweep(args: &Args) -> i32 {
    use imcsim::arch::{ImcFamily, ImcMacro, ImcSystem};
    use imcsim::dse::pareto_front;

    if let Err(e) = reject_unknown(args, "archsweep", &["network", "family", "cells", "threads"]) {
        eprintln!("{e}");
        return 2;
    }
    let threads = match parse_threads(args) {
        Ok(n) => n,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let net = match args.opt("network") {
        Some("ae") | Some("autoencoder") => imcsim::workload::deep_autoencoder(),
        Some("resnet8") => imcsim::workload::resnet8(),
        Some("dscnn") | Some("ds-cnn") => imcsim::workload::ds_cnn(),
        Some("mobilenet") => imcsim::workload::mobilenet_v1(),
        other => {
            eprintln!("--network must be ae|resnet8|dscnn|mobilenet (got {other:?})");
            return 2;
        }
    };
    let families: Vec<ImcFamily> = match args.opt("family") {
        Some("aimc") => vec![ImcFamily::Aimc],
        Some("dimc") => vec![ImcFamily::Dimc],
        None => vec![ImcFamily::Aimc, ImcFamily::Dimc],
        Some(other) => {
            eprintln!("unknown family '{other}'");
            return 2;
        }
    };
    let cells: usize = match args.opt_parse("cells") {
        None => 1152 * 256,
        Some(Ok(n)) if n > 0 => n,
        _ => {
            eprintln!("--cells must be a positive integer");
            return 2;
        }
    };

    // geometry grid: rows x cols per macro, 4b/4b, macro count from the
    // cell budget (the Table II normalization). The memoized cost cache
    // shares layer searches across geometries through the same pruned
    // streaming search the grid sweep uses.
    let rows_grid = [48usize, 64, 128, 256, 512, 1152];
    let cols_grid = [4usize, 32, 64, 128, 256];
    let cache = CostCache::new();
    let mut points = Vec::new();
    let t0 = Instant::now();
    for family in &families {
        for &rows in &rows_grid {
            for &cols in &cols_grid {
                let (dac, adc) = match family {
                    ImcFamily::Aimc => (4, 8),
                    ImcFamily::Dimc => (1, 0),
                };
                let m = ImcMacro::new(
                    &format!("{}_{rows}x{cols}", family.as_str().to_lowercase()),
                    *family, rows, cols, 4, 4, dac, adc, 0.8, 28.0,
                );
                if m.validate().is_err() {
                    continue;
                }
                let name = m.name.clone();
                let sys = ImcSystem::new(&name, m, 1).normalized_to_cells(cells);
                let r = imcsim::dse::search_network_with(
                    &net,
                    &sys,
                    &DseOptions::default(),
                    &cache,
                    threads,
                );
                // Pareto energy axis: macro + buffer level (DRAM traffic
                // is geometry-independent and would flatten the sweep)
                let e_macro = r.macro_breakdown().total_fj() + r.traffic_breakdown().gb_fj;
                points.push((
                    name,
                    sys.n_macros,
                    e_macro,
                    r.total_time_ns(),
                    r.mean_utilization(),
                ));
            }
        }
    }
    let et: Vec<(f64, f64)> = points.iter().map(|p| (p.2, p.3)).collect();
    let front = pareto_front(&et);
    let mut t = Table::new(&[
        "design", "macros", "E_macro+GB [uJ]", "t [us]", "util", "pareto",
    ]);
    let mut sorted: Vec<usize> = (0..points.len()).collect();
    sorted.sort_by(|&a, &b| points[a].2.partial_cmp(&points[b].2).unwrap());
    for i in sorted {
        let p = &points[i];
        t.row(vec![
            p.0.clone(),
            p.1.to_string(),
            format!("{:.3}", p.2 * 1e-9),
            format!("{:.1}", p.3 * 1e-3),
            format!("{:.1}%", p.4 * 100.0),
            if front.contains(&i) { "*".into() } else { String::new() },
        ]);
    }
    println!(
        "architecture sweep: {} on {} geometries at {} cells ({:.2}s)
",
        net.name,
        points.len(),
        cells,
        t0.elapsed().as_secs_f64()
    );
    println!("{}", t.render());
    println!("(* = (energy, latency) Pareto-optimal at equal SRAM budget)");
    let s = cache.stats();
    println!(
        "mapping search: {} candidates — {} evaluated, {} pruned by bound ({:.1}%); \
         cost cache: {} entries, {} hits / {} lookups",
        s.candidates(),
        s.evaluated,
        s.pruned,
        s.prune_rate() * 100.0,
        s.entries,
        s.hits,
        s.lookups()
    );
    0
}

fn artifacts_dir(args: &Args) -> PathBuf {
    args.opt("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(default_artifacts_dir)
}

fn cmd_artifacts(args: &Args) -> i32 {
    let dir = artifacts_dir(args);
    match load_manifest(&dir) {
        Ok(m) => {
            println!("artifacts in {} (batch tile {}):", dir.display(), m.batch);
            for (name, d) in &m.designs {
                println!(
                    "  {name:12} {}  {}x{} (D1={})  {}b/{}b  dac={} adc={}  [{} | {}]",
                    d.config.family,
                    d.config.rows,
                    d.config.d1 * d.config.weight_bits as usize,
                    d.config.d1,
                    d.config.act_bits,
                    d.config.weight_bits,
                    d.config.dac_res,
                    d.config.adc_res,
                    d.mvm.path.file_name().unwrap().to_string_lossy(),
                    d.reference.path.file_name().unwrap().to_string_lossy(),
                );
            }
            0
        }
        Err(e) => {
            eprintln!("{e}\nrun `make artifacts` first");
            1
        }
    }
}

/// The columns of the serve table/CSV, in output order.
const SERVE_HEADERS: [&str; 16] = [
    "design", "network", "schedule", "trace", "requests", "max_batch", "util", "batches",
    "p50_ps", "p99_ps", "mean_ps", "max_ps", "fj_per_req", "reload_fj_per_req", "achieved_rps",
    "slo_rps",
];

fn cmd_serve(args: &Args) -> i32 {
    // `--sweep` switches to the serving-configuration search; it is
    // deliberately valueless, so it must branch before reject_unknown
    // (which demands a value for every known option). `--tenants`
    // switches to the multi-tenant replay.
    if args.flag("sweep") || args.opt("sweep").is_some() {
        return cmd_serve_sweep(args);
    }
    if args.flag("tenants") || args.opt("tenants").is_some() {
        return cmd_serve_tenants(args);
    }
    if let Err(e) = reject_unknown(
        args,
        "serve",
        &[
            "design", "network", "schedule", "batch", "util", "trace", "requests", "seed",
            "burst-period-us", "burst-duty", "slo-ms", "batching", "csv", "threads",
        ],
    ) {
        eprintln!("{e}");
        return 2;
    }
    let threads = match parse_threads(args) {
        Ok(n) => n,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    // axis lists (comma forms, the sweep convention)
    let all = table2_systems();
    let systems: Vec<imcsim::arch::ImcSystem> = match args.opt("design") {
        Some(raw) => {
            let names = match parse_list::<String>(raw, "design") {
                Ok(n) => n,
                Err(e) => {
                    eprintln!("{e}");
                    return 2;
                }
            };
            let mut picked = Vec::new();
            for name in names {
                match all.iter().find(|s| s.name == name) {
                    Some(s) => picked.push(s.clone()),
                    None => {
                        eprintln!("unknown design '{name}'");
                        return 2;
                    }
                }
            }
            picked
        }
        None => all,
    };
    let networks: Vec<imcsim::workload::Network> = {
        let mut nets = Vec::new();
        for token in args.opt_or("network", "ae,resnet8,dscnn,mobilenet").split(',') {
            match token.trim() {
                "ae" | "autoencoder" => nets.push(imcsim::workload::deep_autoencoder()),
                "resnet8" => nets.push(imcsim::workload::resnet8()),
                "dscnn" | "ds-cnn" => nets.push(imcsim::workload::ds_cnn()),
                "mobilenet" => nets.push(imcsim::workload::mobilenet_v1()),
                other => {
                    eprintln!("--network must be ae|resnet8|dscnn|mobilenet (got '{other}')");
                    return 2;
                }
            }
        }
        nets
    };
    let schedules: Vec<Schedule> =
        match parse_list(args.opt_or("schedule", "serialized"), "schedule") {
            Ok(s) => s,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
    let batches: Vec<usize> = match parse_list(args.opt_or("batch", "1,8"), "batch") {
        Ok(b) if b.iter().all(|&b| b >= 1) => b,
        Ok(_) => {
            eprintln!("--batch values must be at least 1");
            return 2;
        }
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let utils: Vec<f64> = match parse_list(args.opt_or("util", "0.8"), "util") {
        Ok(u) if u.iter().all(|&u| u > 0.0 && u <= 1.0) => u,
        Ok(_) => {
            eprintln!("--util values must be in (0, 1]");
            return 2;
        }
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let trace: TraceKind = match args.opt_or("trace", "poisson").parse() {
        Ok(t) => t,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let requests: usize = match args.opt_or("requests", "512").parse() {
        Ok(n) if n > 0 => n,
        _ => {
            eprintln!("--requests must be a positive integer");
            return 2;
        }
    };
    let seed: u64 = match args.opt_or("seed", "42").parse() {
        Ok(s) => s,
        Err(_) => {
            eprintln!("--seed must be an unsigned integer");
            return 2;
        }
    };
    let burst_period_ps: u64 = match args.opt_or("burst-period-us", "100").parse::<f64>() {
        Ok(us) if us > 0.0 => (us * 1e6).round() as u64,
        _ => {
            eprintln!("--burst-period-us must be a positive number");
            return 2;
        }
    };
    let burst_duty: u64 = match args.opt_or("burst-duty", "20").parse() {
        Ok(d) if (1..=100).contains(&d) => d,
        _ => {
            eprintln!("--burst-duty must be a percentage in 1..=100");
            return 2;
        }
    };
    let slo_ps: u64 = match args.opt_or("slo-ms", "2").parse::<f64>() {
        Ok(ms) if ms > 0.0 => (ms * 1e9).round() as u64,
        _ => {
            eprintln!("--slo-ms must be a positive number");
            return 2;
        }
    };
    let per_stage = match args.opt_or("batching", "global") {
        "global" => false,
        "per-stage" => true,
        other => {
            eprintln!("--batching must be global|per-stage (got '{other}')");
            return 2;
        }
    };
    if per_stage && schedules.iter().any(|&s| s != Schedule::LayerPipelined) {
        eprintln!(
            "--batching per-stage rebatches at pipeline stage boundaries and only \
             applies to --schedule layer-pipelined"
        );
        return 2;
    }

    // phase 1: one cost-model search per (design, network) pair, fanned
    // across pairs through the memoized cost cache (energy-optimal
    // mappings, the DseOptions default — the serving-relevant choice)
    let t0 = Instant::now();
    let cache = CostCache::new();
    let pairs: Vec<(usize, usize)> = systems
        .iter()
        .enumerate()
        .flat_map(|(si, _)| (0..networks.len()).map(move |ni| (si, ni)))
        .collect();
    let costs: Vec<NetworkServeCost> = parallel_map_with(&pairs, threads, |&(si, ni)| {
        let r = search_network_with(
            &networks[ni],
            &systems[si],
            &DseOptions::default(),
            &cache,
            1,
        );
        NetworkServeCost::from_result(&r, &systems[si])
    });

    // phase 2: replay every (pair, schedule, batch, util) cell; the fan
    // preserves input order, so the table is thread-count-invariant
    let mut cells: Vec<(usize, Schedule, usize, f64)> = Vec::new();
    for pi in 0..pairs.len() {
        for &schedule in &schedules {
            for &max_batch in &batches {
                for &util in &utils {
                    cells.push((pi, schedule, max_batch, util));
                }
            }
        }
    }
    let rows = parallel_map_with(&cells, threads, |&(pi, schedule, max_batch, util)| {
        let cost = &costs[pi];
        // offered load: util × the schedule's amortized batch capacity
        let interval = cost.bottleneck_ps(schedule, max_batch) as f64 / max_batch as f64;
        let mean_gap = rung_gap_ps(interval, util);
        let arrivals = match trace {
            TraceKind::Poisson => poisson_arrivals(seed, mean_gap, requests),
            TraceKind::Bursty => {
                bursty_arrivals(seed, mean_gap, requests, burst_period_ps, burst_duty)
            }
        };
        let (rep, slo_rps) = if per_stage {
            // heterogeneous per-layer batching: every pipeline stage
            // rebatches independently, so the SLO ladder must replay
            // through the per-stage engine too
            let table = StageTable::new(cost, max_batch);
            let rep = simulate_per_stage(&table, &arrivals);
            let slo_rps = slo_throughput_with(
                cost.min_service_ps(),
                interval,
                seed,
                requests,
                slo_ps,
                |gap| replay_outcome_per_stage(&table, seed, requests, gap),
            );
            (rep, slo_rps)
        } else {
            let rep = simulate(cost, schedule, max_batch, &arrivals);
            let slo_rps = slo_throughput(cost, schedule, max_batch, seed, requests, slo_ps);
            (rep, slo_rps)
        };
        vec![
            cost.system.clone(),
            cost.network.clone(),
            schedule.to_string(),
            trace.to_string(),
            requests.to_string(),
            max_batch.to_string(),
            util.to_string(),
            rep.batches.to_string(),
            rep.latency.percentile_ps(50.0).to_string(),
            rep.latency.percentile_ps(99.0).to_string(),
            rep.latency.mean_ps().to_string(),
            rep.latency.max_ps().to_string(),
            rep.latency.fj_per_request().to_string(),
            rep.latency.reload_fj_per_request().to_string(),
            rep.achieved_rps.to_string(),
            slo_rps.to_string(),
        ]
    });

    let mut t = Table::new(&SERVE_HEADERS);
    for row in rows {
        t.row(row);
    }
    println!("{}", t.render());
    println!(
        "{} cells ({} searches) in {:.2}s — seed {seed}, trace {trace}, SLO p99 <= {} ms",
        cells.len(),
        pairs.len(),
        t0.elapsed().as_secs_f64(),
        slo_ps as f64 / 1e9
    );
    if let Some(path) = args.opt("csv") {
        if let Err(e) = std::fs::write(path, t.to_csv()) {
            eprintln!("cannot write csv: {e}");
            return 1;
        }
        println!("wrote {path}");
    }
    0
}

/// The columns of the `serve --tenants` table/CSV, in output order:
/// one row per (cell, tenant) plus an aggregate `*` row per cell. The
/// cell-level ladder goodput and switch count appear only on the `*`
/// row (`-` elsewhere); `-` likewise marks aggregates that have no
/// meaningful pooled value (latency percentiles across tenants with
/// different SLOs).
const TENANT_HEADERS: [&str; 23] = [
    "design", "schedule", "policy", "tenant", "network", "requests", "max_batch", "slo_ms",
    "admitted", "served", "rejected", "batches", "swaps", "swap_stall_ps", "swap_fj", "p50_ps",
    "p99_ps", "mean_ps", "fj_per_req", "slo_ok", "achieved_rps", "switches", "goodput_rps",
];

/// `serve --tenants`: the multi-tenant replay. Every listed tenant
/// time-shares each design under each (schedule, policy) cell —
/// weight-swap stalls/energy charged on switch-ins, SLO admission
/// control up front, and the dispatch policy arbitrating ready
/// tenants. Cells fan across threads through the memoized tenant
/// store; rows are pure functions of their cell, so the table is
/// byte-identical for every `--threads` count (the CI determinism job
/// `cmp`s exactly that, for FIFO and DRR).
fn cmd_serve_tenants(args: &Args) -> i32 {
    let tenant_args = match args.opt("tenants") {
        Some(raw) => match parse_tenants(raw) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        },
        None => {
            eprintln!("--tenants requires a value (a comma-separated tenant list)");
            return 2;
        }
    };
    if let Err(e) = reject_unknown(
        args,
        "serve --tenants",
        &["tenants", "design", "schedule", "policy", "batch", "requests", "seed", "csv", "threads"],
    ) {
        eprintln!("{e}");
        return 2;
    }
    let threads = match parse_threads(args) {
        Ok(n) => n,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let all = table2_systems();
    let systems: Vec<imcsim::arch::ImcSystem> = match args.opt("design") {
        Some(raw) => {
            let names = match parse_list::<String>(raw, "design") {
                Ok(n) => n,
                Err(e) => {
                    eprintln!("{e}");
                    return 2;
                }
            };
            let mut picked = Vec::new();
            for name in names {
                match all.iter().find(|s| s.name == name) {
                    Some(s) => picked.push(s.clone()),
                    None => {
                        eprintln!("unknown design '{name}'");
                        return 2;
                    }
                }
            }
            picked
        }
        None => all,
    };
    let schedules: Vec<Schedule> =
        match parse_list(args.opt_or("schedule", "layer-pipelined"), "schedule") {
            Ok(s) => s,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
    let policies: Vec<DispatchPolicy> = match parse_list(args.opt_or("policy", "fifo"), "policy") {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let max_batch: usize = match args.opt_or("batch", "8").parse() {
        Ok(n) if n >= 1 => n,
        _ => {
            eprintln!("--batch must be a positive integer");
            return 2;
        }
    };
    let requests: usize = match args.opt_or("requests", "512").parse() {
        Ok(n) if n > 0 => n,
        _ => {
            eprintln!("--requests must be a positive integer");
            return 2;
        }
    };
    let seed: u64 = match args.opt_or("seed", "42").parse() {
        Ok(s) => s,
        Err(_) => {
            eprintln!("--seed must be an unsigned integer");
            return 2;
        }
    };
    // resolve each tenant's network once (distinct tokens, first-seen
    // order) so repeated tenants of one network share a single search
    let mut net_tokens: Vec<String> = Vec::new();
    let mut net_index: Vec<usize> = Vec::with_capacity(tenant_args.len());
    let mut networks: Vec<imcsim::workload::Network> = Vec::new();
    for a in &tenant_args {
        let net = match a.network.as_str() {
            "ae" | "autoencoder" => imcsim::workload::deep_autoencoder(),
            "resnet8" => imcsim::workload::resnet8(),
            "dscnn" | "ds-cnn" => imcsim::workload::ds_cnn(),
            "mobilenet" => imcsim::workload::mobilenet_v1(),
            other => {
                eprintln!("--tenants: network must be ae|resnet8|dscnn|mobilenet (got '{other}')");
                return 2;
            }
        };
        match net_tokens.iter().position(|t| *t == a.network) {
            Some(i) => net_index.push(i),
            None => {
                net_index.push(net_tokens.len());
                net_tokens.push(a.network.clone());
                networks.push(net);
            }
        }
    }

    // phase 1: one cost-model search per (design, distinct network)
    // pair — the same fan `serve` uses
    let t0 = Instant::now();
    let cache = CostCache::new();
    let pairs: Vec<(usize, usize)> = systems
        .iter()
        .enumerate()
        .flat_map(|(si, _)| (0..networks.len()).map(move |ni| (si, ni)))
        .collect();
    let costs: Vec<NetworkServeCost> = parallel_map_with(&pairs, threads, |&(si, ni)| {
        let r = search_network_with(
            &networks[ni],
            &systems[si],
            &DseOptions::default(),
            &cache,
            1,
        );
        NetworkServeCost::from_result(&r, &systems[si])
    });

    // phase 2: one multi-tenant replay + goodput ladder per (design,
    // schedule, policy) cell, through the memoized tenant store
    let mut cells: Vec<(usize, Schedule, DispatchPolicy)> = Vec::new();
    for si in 0..systems.len() {
        for &schedule in &schedules {
            for &policy in &policies {
                cells.push((si, schedule, policy));
            }
        }
    }
    let cell_rows: Vec<Vec<Vec<String>>> =
        parallel_map_with(&cells, threads, |&(si, schedule, policy)| {
            let specs: Vec<TenantSpec> = tenant_args
                .iter()
                .enumerate()
                .map(|(k, a)| {
                    let cost = costs[si * networks.len() + net_index[k]].clone();
                    a.into_spec(cost, schedule, max_batch, tenant_args.len())
                })
                .collect();
            let (out, goodput) =
                cache.tenant_point(&specs, schedule, policy, max_batch, seed, requests);
            let design = &systems[si].name;
            let mut rows = Vec::with_capacity(specs.len() + 1);
            for (spec, p) in specs.iter().zip(out.per_tenant.iter()) {
                rows.push(vec![
                    design.clone(),
                    schedule.to_string(),
                    policy.to_string(),
                    spec.name.clone(),
                    spec.cost.network.clone(),
                    requests.to_string(),
                    max_batch.to_string(),
                    (spec.slo_ps as f64 / 1e9).to_string(),
                    p.admitted.to_string(),
                    p.served.to_string(),
                    p.rejected.to_string(),
                    p.batches.to_string(),
                    p.swaps.to_string(),
                    p.swap_stall_ps.to_string(),
                    p.swap_fj.to_string(),
                    p.p50_ps.to_string(),
                    p.p99_ps.to_string(),
                    p.mean_ps.to_string(),
                    p.fj_per_req.to_string(),
                    p.slo_ok.to_string(),
                    p.achieved_rps.to_string(),
                    "-".into(),
                    "-".into(),
                ]);
            }
            // the aggregate row: sums where pooling is meaningful, the
            // cell-global switch count and ladder goodput, `-` elsewhere
            let served: usize = out.per_tenant.iter().map(|p| p.served).sum();
            let rejected: usize = out.per_tenant.iter().map(|p| p.rejected).sum();
            let batches: usize = out.per_tenant.iter().map(|p| p.batches).sum();
            let swaps: usize = out.per_tenant.iter().map(|p| p.swaps).sum();
            let stall: u64 = out.per_tenant.iter().map(|p| p.swap_stall_ps).sum();
            let swap_fj: f64 = out.per_tenant.iter().map(|p| p.swap_fj).sum();
            let slo_ok: usize = out.per_tenant.iter().map(|p| p.slo_ok).sum();
            let admitted = out.per_tenant.iter().filter(|p| p.admitted).count();
            let achieved = if out.last_done_ps == 0 {
                0.0
            } else {
                served as f64 * 1e12 / out.last_done_ps as f64
            };
            rows.push(vec![
                design.clone(),
                schedule.to_string(),
                policy.to_string(),
                "*".into(),
                "*".into(),
                requests.to_string(),
                max_batch.to_string(),
                "-".into(),
                admitted.to_string(),
                served.to_string(),
                rejected.to_string(),
                batches.to_string(),
                swaps.to_string(),
                stall.to_string(),
                swap_fj.to_string(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                slo_ok.to_string(),
                achieved.to_string(),
                out.switches.to_string(),
                goodput.to_string(),
            ]);
            rows
        });

    let mut t = Table::new(&TENANT_HEADERS);
    for rows in cell_rows {
        for row in rows {
            t.row(row);
        }
    }
    println!("{}", t.render());
    let s = cache.stats();
    println!(
        "{} cells x {} tenants ({} searches) in {:.2}s — seed {seed}, {requests} \
         requests/tenant, batch <= {max_batch}",
        cells.len(),
        tenant_args.len(),
        pairs.len(),
        t0.elapsed().as_secs_f64(),
    );
    println!(
        "serve cache: {} serve entries, {} hits / {} replays ({} duplicated), \
         {} of {} requests replayed ({:.1}x replay reduction)",
        s.serve_entries,
        s.serve_hits,
        s.serve_replays,
        s.duplicate_serves,
        s.serve_replayed_reqs,
        s.serve_naive_reqs,
        s.serve_replay_reduction()
    );
    if let Some(path) = args.opt("csv") {
        if let Err(e) = std::fs::write(path, t.to_csv()) {
            eprintln!("cannot write csv: {e}");
            return 1;
        }
        println!("wrote {path}");
    }
    0
}

/// The columns of the `serve --sweep` best-config table/CSV, in
/// output order: the canonical-trace point beside the search winner.
const SERVE_SWEEP_HEADERS: [&str; 10] = [
    "design", "network", "requests", "slo_ms", "serve_rps", "serve_fj_per_req", "serve_p99_ns",
    "best_serve_schedule", "best_serve_batch", "best_serve_rps",
];

/// `serve --sweep`: the serving-configuration search. For each
/// (design, network) pair, search schedule × batch cap for the best
/// SLO-constrained throughput through the memoized serve store —
/// identical ladder rungs across configs and pairs replay once, and
/// the admissible per-config upper bound retires dominated configs
/// without replaying their ladders. The row fan preserves input
/// order and every row is a pure function of its pair, so the table
/// is byte-identical for every `--threads` count (the CI determinism
/// job `cmp`s exactly that).
fn cmd_serve_sweep(args: &Args) -> i32 {
    if args.opt("sweep").is_some() {
        eprintln!("--sweep takes no value (it selects the serving-config search mode)");
        return 2;
    }
    // reject_unknown demands a value for every known option and
    // --sweep is valueless by design — strip it before the guard.
    let mut rest = args.clone();
    rest.flags.retain(|f| f != "sweep");
    if let Err(e) = reject_unknown(
        &rest,
        "serve --sweep",
        &["design", "network", "requests", "seed", "slo-ms", "csv", "threads"],
    ) {
        eprintln!("{e}");
        return 2;
    }
    let threads = match parse_threads(args) {
        Ok(n) => n,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let all = table2_systems();
    let systems: Vec<imcsim::arch::ImcSystem> = match args.opt("design") {
        Some(raw) => {
            let names = match parse_list::<String>(raw, "design") {
                Ok(n) => n,
                Err(e) => {
                    eprintln!("{e}");
                    return 2;
                }
            };
            let mut picked = Vec::new();
            for name in names {
                match all.iter().find(|s| s.name == name) {
                    Some(s) => picked.push(s.clone()),
                    None => {
                        eprintln!("unknown design '{name}'");
                        return 2;
                    }
                }
            }
            picked
        }
        None => all,
    };
    let networks: Vec<imcsim::workload::Network> = {
        let mut nets = Vec::new();
        for token in args.opt_or("network", "ae,resnet8,dscnn,mobilenet").split(',') {
            match token.trim() {
                "ae" | "autoencoder" => nets.push(imcsim::workload::deep_autoencoder()),
                "resnet8" => nets.push(imcsim::workload::resnet8()),
                "dscnn" | "ds-cnn" => nets.push(imcsim::workload::ds_cnn()),
                "mobilenet" => nets.push(imcsim::workload::mobilenet_v1()),
                other => {
                    eprintln!("--network must be ae|resnet8|dscnn|mobilenet (got '{other}')");
                    return 2;
                }
            }
        }
        nets
    };
    let requests: usize = match args.opt_or("requests", "512").parse() {
        Ok(n) if n > 0 => n,
        _ => {
            eprintln!("--requests must be a positive integer");
            return 2;
        }
    };
    let seed: u64 = match args.opt_or("seed", "42").parse() {
        Ok(s) => s,
        Err(_) => {
            eprintln!("--seed must be an unsigned integer");
            return 2;
        }
    };
    let slo_ps: u64 = match args.opt_or("slo-ms", "2").parse::<f64>() {
        Ok(ms) if ms > 0.0 => (ms * 1e9).round() as u64,
        _ => {
            eprintln!("--slo-ms must be a positive number");
            return 2;
        }
    };
    let serve_cfg = ServeConfig { seed, requests, slo_ps };

    // phase 1: one cost-model search per (design, network) pair — the
    // same fan `serve` uses
    let t0 = Instant::now();
    let cache = CostCache::new();
    let pairs: Vec<(usize, usize)> = systems
        .iter()
        .enumerate()
        .flat_map(|(si, _)| (0..networks.len()).map(move |ni| (si, ni)))
        .collect();
    let costs: Vec<NetworkServeCost> = parallel_map_with(&pairs, threads, |&(si, ni)| {
        let r = search_network_with(
            &networks[ni],
            &systems[si],
            &DseOptions::default(),
            &cache,
            1,
        );
        NetworkServeCost::from_result(&r, &systems[si])
    });

    // phase 2: per pair, the canonical-trace point and the pruned
    // config search, both through the memoized serve store
    let idx: Vec<usize> = (0..pairs.len()).collect();
    let rows = parallel_map_with(&idx, threads, |&pi| {
        let cost = &costs[pi];
        let point = cache.serve_point(cost, &serve_cfg);
        let best = cache.best_serve_config(cost, &serve_cfg);
        vec![
            cost.system.clone(),
            cost.network.clone(),
            requests.to_string(),
            (slo_ps as f64 / 1e9).to_string(),
            point.rps.to_string(),
            point.fj_per_req.to_string(),
            point.p99_ns.to_string(),
            best.schedule.to_string(),
            best.max_batch.to_string(),
            best.rps.to_string(),
        ]
    });

    let mut t = Table::new(&SERVE_SWEEP_HEADERS);
    for row in rows {
        t.row(row);
    }
    println!("{}", t.render());
    let s = cache.stats();
    println!(
        "{} (design, network) pairs in {:.2}s — seed {seed}, {requests} requests, \
         SLO p99 <= {} ms",
        pairs.len(),
        t0.elapsed().as_secs_f64(),
        slo_ps as f64 / 1e9
    );
    println!(
        "serve cache: {} serve entries, {} hits / {} replays ({} duplicated), \
         {} of {} requests replayed ({:.1}x replay reduction)",
        s.serve_entries,
        s.serve_hits,
        s.serve_replays,
        s.duplicate_serves,
        s.serve_replayed_reqs,
        s.serve_naive_reqs,
        s.serve_replay_reduction()
    );
    if let Some(path) = args.opt("csv") {
        if let Err(e) = std::fs::write(path, t.to_csv()) {
            eprintln!("cannot write csv: {e}");
            return 1;
        }
        println!("wrote {path}");
    }
    0
}
