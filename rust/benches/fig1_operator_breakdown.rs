//! Bench E1 (paper Fig. 1): operator breakdown of the tinyMLPerf models.
//! Prints the figure data and times its computation.

use imcsim::report::fig1_text;
use imcsim::util::bench::{report_metric, Bench};
use imcsim::workload::all_networks;

fn main() {
    let mut b = Bench::from_args();
    println!("{}", fig1_text());
    for net in all_networks() {
        report_metric(
            &format!("fig1/{}/total_MMACs", net.name),
            net.total_macs() as f64 / 1e6,
            "MMAC",
        );
    }
    b.bench("fig1/operator_breakdown", || {
        all_networks()
            .iter()
            .map(|n| n.operator_breakdown().total_macs)
            .sum::<u64>()
    });
}
