//! Perf bench: the grid-sweep pipeline — memoized vs exhaustive layer
//! search, pruned vs unpruned mapping search, and a mini-grid
//! end-to-end run at several shard widths. Reports the cache hit rate
//! and the bound-pruning evaluation reduction the full survey grid
//! achieves (the acceptance bar is ≥2× fewer full cost evaluations).

use imcsim::arch::table2_systems;
use imcsim::dse::{
    search_layer, search_layer_all, search_layer_all_unpruned, DseOptions, LayerEvaluator,
    COST_OBJECTIVES, DEFAULT_SPARSITY,
};
use imcsim::model::TechParams;
use imcsim::sweep::{run_sweep, CostCache, PrecisionPoint, SweepGrid, SweepOptions};
use imcsim::util::bench::{report_metric, Bench};
use imcsim::workload::{deep_autoencoder, ds_cnn, Layer};

fn main() {
    let mut b = Bench::from_args();
    let systems = table2_systems();
    let sys = &systems[1];
    let tech = TechParams::for_node(sys.imc.tech_nm);
    let layer = Layer::conv2d("c", 16, 16, 32, 16, 3, 3, 1);
    let opts = DseOptions::default();

    // the uncached baseline: a full mapping search per call
    if let Some(cold) = b.bench("sweep/layer_search_uncached", || {
        search_layer(&layer, sys, &tech, &opts).best.time_ns
    }) {
        // the memoized path after warm-up: a key build + map lookup
        let cache = CostCache::new();
        cache.evaluate_layer(&layer, sys, &tech, &opts);
        if let Some(warm) = b.bench("sweep/layer_search_cached", || {
            cache.evaluate_layer(&layer, sys, &tech, &opts).best.time_ns
        }) {
            report_metric(
                "sweep/cache_speedup",
                cold.median_ns / warm.median_ns.max(1.0),
                "x",
            );
        }
    }

    // pruned vs unpruned single-layer search (identical optima; the
    // pruned pass skips full evaluation for bound-dominated candidates)
    if let Some(pruned) = b.bench("sweep/layer_search_pruned", || {
        search_layer_all(&layer, sys, &tech, DEFAULT_SPARSITY, None).evaluated
    }) {
        if let Some(unpruned) = b.bench("sweep/layer_search_unpruned", || {
            search_layer_all_unpruned(&layer, sys, &tech, DEFAULT_SPARSITY, None).evaluated
        }) {
            report_metric(
                "sweep/prune_time_speedup",
                unpruned.median_ns / pruned.median_ns.max(1.0),
                "x",
            );
        }
    }

    // mini-grid end-to-end at different shard widths
    let grid = SweepGrid {
        systems: systems.clone(),
        networks: vec![deep_autoencoder(), ds_cnn()],
        precisions: vec![PrecisionPoint::Native],
        sparsities: vec![DEFAULT_SPARSITY],
        objectives: COST_OBJECTIVES.to_vec(),
    };
    for threads in [1usize, 4] {
        let name = format!("sweep/mini_grid_{threads}_threads");
        b.bench(&name, || {
            let run = SweepOptions {
                threads,
                ..Default::default()
            };
            run_sweep(&grid, &run).points.len()
        });
    }

    // evaluation-reduction on the mini grid (cheap enough for --quick)
    {
        let s = run_sweep(&grid, &SweepOptions::default());
        let evaluated = s.cache.evaluated.max(1) as f64;
        report_metric(
            "sweep/mini_grid_eval_reduction",
            s.cache.candidates() as f64 / evaluated,
            "x",
        );
    }

    // the headline metrics: cache effectiveness and bound-pruning
    // reduction on the real survey grid (the most expensive section —
    // skipped under --quick or when filtered out, like any timed
    // benchmark)
    if b.enabled("sweep/survey_cache") && !b.is_quick() {
        let survey = SweepGrid::survey_tinymlperf(imcsim::sweep::DEFAULT_GRID_CELLS);
        let s = run_sweep(&survey, &SweepOptions::default());
        let hit_pct = s.cache.hit_rate() * 100.0;
        let entries = s.cache.entries as f64;
        report_metric("sweep/survey_grid_tasks", s.points.len() as f64, "tasks");
        report_metric("sweep/survey_cache_hit_rate", hit_pct, "%");
        report_metric("sweep/survey_cache_entries", entries, "entries");
        // candidates / evaluated: how many fewer full evaluate() calls
        // the admissible bound buys on the default grid (target: >= 2x)
        report_metric("sweep/survey_candidates", s.cache.candidates() as f64, "cands");
        report_metric("sweep/survey_evaluated", s.cache.evaluated as f64, "evals");
        report_metric(
            "sweep/survey_eval_reduction",
            s.cache.candidates() as f64 / s.cache.evaluated.max(1) as f64,
            "x",
        );
    }
}
