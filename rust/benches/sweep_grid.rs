//! Perf bench: the grid-sweep pipeline — memoized vs exhaustive layer
//! search, pruned vs unpruned mapping search, scalar vs bit-plane
//! simulator, and a mini-grid end-to-end run at several shard widths.
//! Reports the cache hit rate, the bound-pruning evaluation reduction
//! the full survey grid achieves (the acceptance bar is ≥2× fewer full
//! cost evaluations), and the bit-plane simulator's speedup over the
//! retained scalar reference (the acceptance bar is ≥5×).
//!
//! Also carries the serving simulator's trajectory points
//! (`serve/replay_4096_reqs` wall time, the modeled req/s and the
//! host-side replay rate) and, on the gate grid, the serve
//! memoization's replay-reduction metrics.
//!
//! With `IMCSIM_BENCH_JSON=PATH` set, the run additionally emits a
//! machine-readable trajectory file (`BENCH_sweep.json` in CI):
//! per-benchmark median timings, every reported metric, a `scaling`
//! object (gate-grid wall time at 1/4/8/16 worker threads), and a
//! `gate` object — evaluated/pruned candidate counts, cache hit rate,
//! wall time, the pruning reduction on the multi-macro acceptance
//! grid, the scalar-vs-bitplane `sim_speedup`, the `cross_corner_rate`
//! of the noise-split cache (the fraction of uncached lookups on the
//! two-corner gate grid that skipped the mapping search), the
//! single-flight `duplicate_searches` tripwire, the 8-thread
//! `wall_speedup_8t` of the (group × layer) scheduler, and the serve
//! store's `serve_replay_reduction` (naive replay volume for the
//! grid's serving columns ÷ requests actually replayed through the
//! memoized, rung-pruned ladder) with its `duplicate_serves`
//! tripwire — that the CI `bench-trajectory` job archives per push
//! and fails on when the reduction drops below 2×, the sim speedup
//! below 5×, the wall speedup below 3×, the serve replay reduction
//! below 10×, or any search or serve replay is ever duplicated.
//!
//! The multi-tenant serving path rides the same gate:
//! `tenant_swap_overhead` (the share of the replay horizon a
//! swap-dominated two-tenant mix on the big AIMC macro stalls on
//! weight swaps) is archived as trajectory, `tenant_replay_reduction`
//! (five repeated two-tenant grid cells through the memoized tenant
//! store ÷ requests actually replayed) is gated at ≥ 5×, and the
//! tenant store's duplicated replays fold into the `duplicate_serves`
//! zero-gate.

use std::collections::BTreeMap;
use std::time::Instant;

use imcsim::arch::table2_systems;
use imcsim::dse::{
    search_layer, search_layer_all, search_layer_all_unpruned, search_network, DseOptions,
    LayerEvaluator, COST_OBJECTIVES, DEFAULT_SPARSITY,
};
use imcsim::model::TechParams;
use imcsim::serve::{
    poisson_arrivals, simulate, DispatchPolicy, NetworkServeCost, Schedule, TenantArg,
    TenantLoadArg,
};
use imcsim::sim::NoiseSpec;
use imcsim::sweep::{run_sweep, CostCache, PrecisionPoint, SweepGrid, SweepOptions};
use imcsim::util::bench::{report_metric, Bench};
use imcsim::util::json::Json;
use imcsim::workload::{deep_autoencoder, ds_cnn, Layer};

fn main() {
    let t_start = Instant::now();
    let mut b = Bench::from_args();
    let mut metrics: Vec<(String, f64)> = Vec::new();
    let metric = |metrics: &mut Vec<(String, f64)>, name: &str, value: f64, unit: &str| {
        report_metric(name, value, unit);
        metrics.push((name.to_string(), value));
    };
    let systems = table2_systems();
    let sys = &systems[1];
    let tech = TechParams::for_node(sys.imc.tech_nm);
    let layer = Layer::conv2d("c", 16, 16, 32, 16, 3, 3, 1);
    let opts = DseOptions::default();

    // the uncached baseline: a full mapping search per call
    if let Some(cold) = b.bench("sweep/layer_search_uncached", || {
        search_layer(&layer, sys, &tech, &opts).best.time_ns
    }) {
        // the memoized path after warm-up: a key build + map lookup
        let cache = CostCache::new();
        cache.evaluate_layer(&layer, sys, &tech, &opts);
        if let Some(warm) = b.bench("sweep/layer_search_cached", || {
            cache.evaluate_layer(&layer, sys, &tech, &opts).best.time_ns
        }) {
            metric(
                &mut metrics,
                "sweep/cache_speedup",
                cold.median_ns / warm.median_ns.max(1.0),
                "x",
            );
        }
    }

    // bit-plane vs scalar bit-true simulator on a representative AIMC
    // design (DIMC gains are larger still: no per-bitline ADC transfer
    // interrupts the popcount loop there)
    let aimc = systems
        .iter()
        .find(|s| s.imc.family == imcsim::arch::ImcFamily::Aimc)
        .expect("table2 carries an AIMC design");
    if let Some(scalar) = b.bench("sweep/sim_layer_scalar", || {
        imcsim::sim::mvm::scalar::layer_accuracy(&layer, &aimc.imc).outputs
    }) {
        if let Some(bitplane) = b.bench("sweep/sim_layer_bitplane", || {
            imcsim::sim::layer_accuracy(&layer, &aimc.imc).outputs
        }) {
            metric(
                &mut metrics,
                "sweep/sim_speedup",
                scalar.median_ns / bitplane.median_ns.max(1.0),
                "x",
            );
        }
    }

    // pruned vs unpruned single-layer search (identical optima; the
    // pruned pass skips full evaluation for bound-dominated candidates)
    if let Some(pruned) = b.bench("sweep/layer_search_pruned", || {
        search_layer_all(&layer, sys, &tech, DEFAULT_SPARSITY, None).evaluated
    }) {
        if let Some(unpruned) = b.bench("sweep/layer_search_unpruned", || {
            search_layer_all_unpruned(&layer, sys, &tech, DEFAULT_SPARSITY, None).evaluated
        }) {
            metric(
                &mut metrics,
                "sweep/prune_time_speedup",
                unpruned.median_ns / pruned.median_ns.max(1.0),
                "x",
            );
        }
    }

    // mini-grid end-to-end at different shard widths
    let grid = SweepGrid {
        systems: systems.clone(),
        networks: vec![deep_autoencoder(), ds_cnn()],
        precisions: vec![PrecisionPoint::Native],
        sparsities: vec![DEFAULT_SPARSITY],
        noises: vec![NoiseSpec::Off],
        objectives: COST_OBJECTIVES.to_vec(),
    };
    for threads in [1usize, 4, 16] {
        let name = format!("sweep/mini_grid_{threads}_threads");
        b.bench(&name, || {
            let run = SweepOptions {
                threads,
                ..Default::default()
            };
            run_sweep(&grid, &run).points.len()
        });
    }

    // the serving simulator: replay wall time and modeled sustained
    // req/s on one representative (design, network) pair — the serving
    // path's first trajectory points (archived, no gate yet)
    {
        let serve_sys = &systems[1];
        let net = ds_cnn();
        let r = search_network(&net, serve_sys, &opts);
        let cost = NetworkServeCost::from_result(&r, serve_sys);
        let interval = cost.bottleneck_ps(Schedule::LayerPipelined, 8) as f64 / 8.0;
        let mean_gap = ((interval / 0.8).round() as u64).max(1);
        let arrivals = poisson_arrivals(42, mean_gap, 4096);
        if let Some(st) = b.bench("serve/replay_4096_reqs", || {
            simulate(&cost, Schedule::LayerPipelined, 8, &arrivals).latency.count()
        }) {
            let rep = simulate(&cost, Schedule::LayerPipelined, 8, &arrivals);
            // modeled throughput of the simulated accelerator...
            metric(&mut metrics, "serve/modeled_rps", rep.achieved_rps, "req/s");
            // ...and the simulator's own replay rate on the host
            metric(
                &mut metrics,
                "serve/replay_reqs_per_wall_sec",
                4096.0 / (st.median_ns * 1e-9).max(1e-12),
                "req/s",
            );
        }
    }

    // evaluation-reduction on the mini grid (cheap enough for --quick)
    {
        let s = run_sweep(&grid, &SweepOptions::default());
        let evaluated = s.cache.evaluated.max(1) as f64;
        metric(
            &mut metrics,
            "sweep/mini_grid_eval_reduction",
            s.cache.candidates() as f64 / evaluated,
            "x",
        );
    }

    // The trajectory gate: the multi-macro, conv-heavy acceptance grid
    // (the mix that dominates the default survey) timed end to end.
    // Its evaluated/pruned counts, hit rate and reduction are what the
    // CI bench-trajectory job archives and gates on (reduction >= 2x),
    // so this section runs exactly when a JSON path is set (CI always
    // sets one) — a filtered or --quick local run without it skips the
    // most expensive grid in the file.
    let json_path = std::env::var("IMCSIM_BENCH_JSON").ok();
    let gate = json_path.as_ref().map(|_| {
        // the gate runs both the off and the typical noise corner: with
        // the noise-split cache the second corner must reuse every
        // mapping search (cross_corner_rate is what proves it)
        let gate_grid = SweepGrid {
            systems: vec![systems[1].clone(), systems[3].clone()],
            networks: vec![imcsim::workload::resnet8(), imcsim::workload::mobilenet_v1()],
            precisions: vec![PrecisionPoint::Native],
            sparsities: vec![DEFAULT_SPARSITY],
            noises: vec![NoiseSpec::Off, NoiseSpec::Typical],
            objectives: COST_OBJECTIVES.to_vec(),
        };
        let t0 = Instant::now();
        let s = run_sweep(&gate_grid, &SweepOptions::default());
        let wall = t0.elapsed().as_secs_f64();
        let reduction = s.cache.candidates() as f64 / s.cache.evaluated.max(1) as f64;
        metric(&mut metrics, "sweep/gate_evaluated", s.cache.evaluated as f64, "evals");
        metric(&mut metrics, "sweep/gate_pruned", s.cache.pruned as f64, "cands");
        metric(&mut metrics, "sweep/gate_eval_reduction", reduction, "x");
        metric(
            &mut metrics,
            "sweep/gate_cache_hit_rate",
            s.cache.hit_rate() * 100.0,
            "%",
        );
        metric(
            &mut metrics,
            "sweep/gate_cross_corner_rate",
            s.cache.cross_corner_rate() * 100.0,
            "%",
        );
        metric(&mut metrics, "sweep/gate_wall_seconds", wall, "s");
        metric(
            &mut metrics,
            "sweep/gate_duplicate_searches",
            s.cache.duplicate_searches as f64,
            "searches",
        );
        // the serving columns' replay economy on the same gate grid:
        // every grid point's canonical point + config search, counted
        // against the naive volume of replaying each from scratch
        metric(
            &mut metrics,
            "serve/gate_replayed_reqs",
            s.cache.serve_replayed_reqs as f64,
            "reqs",
        );
        metric(
            &mut metrics,
            "serve/gate_naive_reqs",
            s.cache.serve_naive_reqs as f64,
            "reqs",
        );
        metric(
            &mut metrics,
            "serve/gate_replay_reduction",
            s.cache.serve_replay_reduction(),
            "x",
        );
        metric(
            &mut metrics,
            "serve/gate_duplicate_serves",
            s.cache.duplicate_serves as f64,
            "replays",
        );

        // multi-tenant serving on the swap-dominated pair: dscnn
        // (resident on the big AIMC macro — every switch-in evicts and
        // reloads its D1 weights) time-sharing with resnet8
        // (non-resident there). tenant_swap_overhead is the share of
        // the replay horizon stalled on swaps; five repeated grid
        // cells through a fresh memoized tenant store measure the
        // warm-path replay economy the CI gates at >= 5x
        let aimc_large = systems
            .iter()
            .find(|s| s.name == "aimc_large")
            .expect("table2 carries aimc_large");
        let tenant_nets = [ds_cnn(), imcsim::workload::resnet8()];
        let tenant_specs: Vec<imcsim::serve::TenantSpec> = tenant_nets
            .iter()
            .map(|net| {
                let r = search_network(net, aimc_large, &opts);
                let cost = NetworkServeCost::from_result(&r, aimc_large);
                TenantArg {
                    name: cost.network.clone(),
                    network: cost.network.clone(),
                    slo_ps: 2_000_000_000,
                    priority: 1,
                    share: 1,
                    util: 0.8,
                    load: TenantLoadArg::Poisson,
                }
                .into_spec(cost, Schedule::LayerPipelined, 8, tenant_nets.len())
            })
            .collect();
        let tcache = CostCache::new();
        let mut tenant_cell = None;
        for _ in 0..5 {
            tenant_cell = Some(tcache.tenant_point(
                &tenant_specs,
                Schedule::LayerPipelined,
                DispatchPolicy::Fifo,
                8,
                42,
                512,
            ));
        }
        let (tenant_out, _goodput) = tenant_cell.expect("five tenant passes ran");
        let stall_ps: u64 = tenant_out.per_tenant.iter().map(|p| p.swap_stall_ps).sum();
        let tenant_swap_overhead = stall_ps as f64 / tenant_out.last_done_ps.max(1) as f64;
        let tstats = tcache.stats();
        let tenant_replay_reduction = tstats.serve_replay_reduction();
        metric(
            &mut metrics,
            "serve/tenant_swap_overhead",
            tenant_swap_overhead,
            "frac",
        );
        metric(
            &mut metrics,
            "serve/tenant_replay_reduction",
            tenant_replay_reduction,
            "x",
        );
        metric(
            &mut metrics,
            "serve/tenant_duplicate_serves",
            tstats.duplicate_serves as f64,
            "replays",
        );

        // thread-scaling on the same gate grid: a fresh cold cache per
        // width (run_sweep builds its own), so every wall time measures
        // the full search workload through the (group × layer)
        // scheduler at that worker count
        let mut scaling: Vec<(usize, f64)> = Vec::new();
        for threads in [1usize, 4, 8, 16] {
            let run = SweepOptions {
                threads,
                ..Default::default()
            };
            let t = Instant::now();
            std::hint::black_box(run_sweep(&gate_grid, &run).points.len());
            let w = t.elapsed().as_secs_f64();
            metric(&mut metrics, &format!("sweep/gate_wall_{threads}t"), w, "s");
            scaling.push((threads, w));
        }
        let wall_1t = scaling[0].1;
        let wall_8t = scaling.iter().find(|&&(t, _)| t == 8).expect("8t ran").1;
        let wall_speedup_8t = wall_1t / wall_8t.max(1e-12);
        metric(&mut metrics, "sweep/gate_wall_speedup_8t", wall_speedup_8t, "x");

        // the scalar-vs-bitplane simulator gate is measured directly
        // (never filtered out: CI always needs a sim_speedup value)
        let median_secs = |f: &mut dyn FnMut() -> u64| {
            let mut ts: Vec<f64> = (0..5)
                .map(|_| {
                    let t = Instant::now();
                    std::hint::black_box(f());
                    t.elapsed().as_secs_f64()
                })
                .collect();
            ts.sort_by(|a, b| a.partial_cmp(b).unwrap());
            ts[ts.len() / 2]
        };
        let t_scalar =
            median_secs(&mut || imcsim::sim::mvm::scalar::layer_accuracy(&layer, &aimc.imc).outputs);
        let t_bitplane =
            median_secs(&mut || imcsim::sim::layer_accuracy(&layer, &aimc.imc).outputs);
        let sim_speedup = t_scalar / t_bitplane.max(1e-12);
        metric(&mut metrics, "sweep/gate_sim_speedup", sim_speedup, "x");
        (
            s.cache,
            reduction,
            wall,
            sim_speedup,
            wall_speedup_8t,
            scaling,
            tenant_swap_overhead,
            tenant_replay_reduction,
            tstats.duplicate_serves,
        )
    });

    // the headline metrics: cache effectiveness and bound-pruning
    // reduction on the real survey grid (the most expensive section —
    // skipped under --quick or when filtered out, like any timed
    // benchmark)
    if b.enabled("sweep/survey_cache") && !b.is_quick() {
        let survey = SweepGrid::survey_tinymlperf(imcsim::sweep::DEFAULT_GRID_CELLS);
        let s = run_sweep(&survey, &SweepOptions::default());
        let hit_pct = s.cache.hit_rate() * 100.0;
        let entries = s.cache.entries as f64;
        metric(&mut metrics, "sweep/survey_grid_tasks", s.points.len() as f64, "tasks");
        metric(&mut metrics, "sweep/survey_cache_hit_rate", hit_pct, "%");
        metric(&mut metrics, "sweep/survey_cache_entries", entries, "entries");
        // candidates / evaluated: how many fewer full evaluate() calls
        // the admissible bound buys on the default grid (target: >= 2x)
        metric(
            &mut metrics,
            "sweep/survey_candidates",
            s.cache.candidates() as f64,
            "cands",
        );
        metric(&mut metrics, "sweep/survey_evaluated", s.cache.evaluated as f64, "evals");
        metric(
            &mut metrics,
            "sweep/survey_eval_reduction",
            s.cache.candidates() as f64 / s.cache.evaluated.max(1) as f64,
            "x",
        );
    }

    // machine-readable trajectory file for the CI bench-trajectory job
    if let Some(path) = json_path {
        let (
            cache,
            reduction,
            gate_wall,
            sim_speedup,
            wall_speedup_8t,
            scaling,
            tenant_swap_overhead,
            tenant_replay_reduction,
            tenant_duplicate_serves,
        ) = gate.expect("gate ran whenever a JSON path is set");
        let num = Json::Num;
        let timings: BTreeMap<String, Json> = b
            .results()
            .iter()
            .map(|(name, st)| (name.clone(), num(st.median_ns)))
            .collect();
        let metric_map: BTreeMap<String, Json> =
            metrics.iter().map(|(n, v)| (n.clone(), num(*v))).collect();
        let gate_obj: BTreeMap<String, Json> = [
            ("evaluated".to_string(), num(cache.evaluated as f64)),
            ("pruned".to_string(), num(cache.pruned as f64)),
            ("candidates".to_string(), num(cache.candidates() as f64)),
            ("reduction".to_string(), num(reduction)),
            ("cache_hit_rate".to_string(), num(cache.hit_rate())),
            ("cross_corner_rate".to_string(), num(cache.cross_corner_rate())),
            ("sim_speedup".to_string(), num(sim_speedup)),
            ("wall_seconds".to_string(), num(gate_wall)),
            (
                "duplicate_searches".to_string(),
                num(cache.duplicate_searches as f64),
            ),
            ("wall_speedup_8t".to_string(), num(wall_speedup_8t)),
            (
                "serve_replay_reduction".to_string(),
                num(cache.serve_replay_reduction()),
            ),
            // the zero-gate covers single-tenant and multi-tenant keys:
            // a duplicated replay in either store trips it
            (
                "duplicate_serves".to_string(),
                num((cache.duplicate_serves + tenant_duplicate_serves) as f64),
            ),
            (
                "serve_replayed_reqs".to_string(),
                num(cache.serve_replayed_reqs as f64),
            ),
            (
                "serve_naive_reqs".to_string(),
                num(cache.serve_naive_reqs as f64),
            ),
            (
                "tenant_swap_overhead".to_string(),
                num(tenant_swap_overhead),
            ),
            (
                "tenant_replay_reduction".to_string(),
                num(tenant_replay_reduction),
            ),
        ]
        .into_iter()
        .collect();
        let scaling_obj: BTreeMap<String, Json> = scaling
            .iter()
            .map(|&(t, w)| (format!("wall_seconds_{t}t"), num(w)))
            .collect();
        let doc: BTreeMap<String, Json> = [
            ("bench".to_string(), Json::Str("sweep_grid".to_string())),
            ("quick".to_string(), Json::Bool(b.is_quick())),
            (
                "total_wall_seconds".to_string(),
                num(t_start.elapsed().as_secs_f64()),
            ),
            ("timings_median_ns".to_string(), Json::Obj(timings)),
            ("metrics".to_string(), Json::Obj(metric_map)),
            ("scaling".to_string(), Json::Obj(scaling_obj)),
            ("gate".to_string(), Json::Obj(gate_obj)),
        ]
        .into_iter()
        .collect();
        let text = Json::Obj(doc).to_string();
        match std::fs::write(&path, &text) {
            Ok(()) => println!("wrote bench trajectory to {path}"),
            Err(e) => {
                eprintln!("cannot write {path}: {e}");
                std::process::exit(1);
            }
        }
    }
}
