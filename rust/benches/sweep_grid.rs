//! Perf bench: the grid-sweep pipeline — memoized vs exhaustive layer
//! search, and a mini-grid end-to-end run at several shard widths.
//! Reports the cache hit rate the full survey grid achieves.

use imcsim::arch::table2_systems;
use imcsim::dse::{search_layer, DseOptions, LayerEvaluator, ALL_OBJECTIVES};
use imcsim::model::TechParams;
use imcsim::sweep::{run_sweep, CostCache, SweepGrid, SweepOptions};
use imcsim::util::bench::{report_metric, Bench};
use imcsim::workload::{deep_autoencoder, ds_cnn, Layer};

fn main() {
    let mut b = Bench::from_args();
    let systems = table2_systems();
    let sys = &systems[1];
    let tech = TechParams::for_node(sys.imc.tech_nm);
    let layer = Layer::conv2d("c", 16, 16, 32, 16, 3, 3, 1);
    let opts = DseOptions::default();

    // the uncached baseline: a full mapping search per call
    if let Some(cold) = b.bench("sweep/layer_search_uncached", || {
        search_layer(&layer, sys, &tech, &opts).best.time_ns
    }) {
        // the memoized path after warm-up: a key build + map lookup
        let cache = CostCache::new();
        cache.evaluate_layer(&layer, sys, &tech, &opts);
        if let Some(warm) = b.bench("sweep/layer_search_cached", || {
            cache.evaluate_layer(&layer, sys, &tech, &opts).best.time_ns
        }) {
            report_metric(
                "sweep/cache_speedup",
                cold.median_ns / warm.median_ns.max(1.0),
                "x",
            );
        }
    }

    // mini-grid end-to-end at different shard widths
    let grid = SweepGrid {
        systems: systems.clone(),
        networks: vec![deep_autoencoder(), ds_cnn()],
        objectives: ALL_OBJECTIVES.to_vec(),
    };
    for threads in [1usize, 4] {
        let name = format!("sweep/mini_grid_{threads}_threads");
        b.bench(&name, || {
            let run = SweepOptions {
                threads,
                ..Default::default()
            };
            run_sweep(&grid, &run).points.len()
        });
    }

    // the headline metric: cache effectiveness on the real survey grid
    // (the most expensive section — skipped under --quick or when
    // filtered out, like any timed benchmark)
    if b.enabled("sweep/survey_cache") && !b.is_quick() {
        let survey = SweepGrid::survey_tinymlperf(imcsim::sweep::DEFAULT_GRID_CELLS);
        let s = run_sweep(&survey, &SweepOptions::default());
        let hit_pct = s.cache.hit_rate() * 100.0;
        let entries = s.cache.entries as f64;
        report_metric("sweep/survey_grid_tasks", s.points.len() as f64, "tasks");
        report_metric("sweep/survey_cache_hit_rate", hit_pct, "%");
        report_metric("sweep/survey_cache_entries", entries, "entries");
    }
}
