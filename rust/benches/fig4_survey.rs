//! Bench E2 (paper Fig. 4): the survey scatter. Prints the figure and
//! reports the headline survey metrics the paper calls out in §III.

use imcsim::db::{fig4_points, survey};
use imcsim::report::fig4_text;
use imcsim::util::bench::{report_metric, Bench};

fn main() {
    let mut b = Bench::from_args();
    println!("{}", fig4_text());

    // §III headlines: best AIMC efficiency ([26]), best density ([32]),
    // DIMC node dependence ([40] 22nm vs [41] 5nm)
    let pts = fig4_points();
    let best_eff = pts
        .iter()
        .filter(|p| p.family == "AIMC")
        .map(|p| p.tops_w)
        .fold(0.0, f64::max);
    report_metric("fig4/best_aimc_tops_w", best_eff, "TOP/s/W");
    let best_dens = pts
        .iter()
        .filter_map(|p| p.tops_mm2)
        .fold(0.0, f64::max);
    report_metric("fig4/best_density", best_dens, "TOP/s/mm2");
    let chih = pts.iter().find(|p| p.chip == "chih_isscc21").unwrap();
    let fuji = pts
        .iter()
        .find(|p| p.chip == "fujiwara_isscc22" && p.vdd > 0.8)
        .unwrap();
    report_metric(
        "fig4/dimc_node_gain_22nm_to_5nm",
        fuji.tops_w / chih.tops_w,
        "x",
    );

    b.bench("fig4/survey_derivation", || {
        fig4_points().len() + survey().len()
    });
}
