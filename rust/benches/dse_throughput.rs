//! Perf bench: the DSE hot path — single mapping-point evaluations and
//! full-layer searches per second (the L3 optimization target of
//! EXPERIMENTS.md §Perf).

use imcsim::arch::table2_systems;
use imcsim::dse::{evaluate, search_layer, DseOptions};
use imcsim::mapping::{candidates, TemporalPolicy};
use imcsim::model::TechParams;
use imcsim::util::bench::{report_metric, Bench};
use imcsim::workload::{resnet8, Layer};

fn main() {
    let mut b = Bench::from_args();
    let systems = table2_systems();
    let sys = &systems[0];
    let tech = TechParams::for_node(sys.imc.tech_nm);
    let layer = Layer::conv2d("c", 16, 16, 32, 16, 3, 3, 1);
    let sp = candidates(&layer, sys).remove(0);

    // single cost-point evaluation (the innermost hot path)
    if let Some(s) = b.bench("dse/evaluate_one_mapping_point", || {
        evaluate(
            &layer,
            sys,
            &tech,
            &sp,
            TemporalPolicy::WeightStationary,
            0.5,
        )
        .total_energy_fj()
    }) {
        report_metric(
            "dse/evaluations_per_sec",
            1e9 / s.median_ns,
            "eval/s (target: >= 100k)",
        );
    }

    // one layer search (candidates x policies)
    b.bench("dse/search_layer", || {
        search_layer(&layer, sys, &tech, &DseOptions::default()).evaluated
    });

    // a full network on the most macro-heavy system (parallel fan-out)
    let net = resnet8();
    let heavy = &systems[3];
    b.bench("dse/search_resnet8_dimc_multi", || {
        imcsim::dse::search_network(&net, heavy, &DseOptions::default())
            .layers
            .len()
    });
}
