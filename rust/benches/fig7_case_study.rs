//! Bench E8 (paper Fig. 7 + Table II): the full case study — four
//! normalized architectures × four tinyMLPerf networks, DSE-optimal
//! mappings, macro-level energy breakdown and data traffic.

use imcsim::arch::table2_systems;
use imcsim::dse::{search_network, DseOptions};
use imcsim::report::{fig7_results, fig7_text, table2_text};
use imcsim::util::bench::{report_metric, Bench};
use imcsim::workload::{all_networks, resnet8};

fn main() {
    let mut b = Bench::from_args();
    println!("{}", table2_text());
    let results = fig7_results();
    println!("{}", fig7_text(&results));

    // headline shape checks as metrics (who wins where, by how much)
    let macro_eff = |net: &str, sys: &str| {
        let r = results
            .iter()
            .find(|r| r.network == net && r.system == sys)
            .unwrap();
        2.0e3 * r.total_macs() as f64
            / (r.macro_breakdown().total_fj() + r.traffic_breakdown().gb_fj)
    };
    report_metric(
        "fig7/dscnn_small_vs_large_aimc",
        macro_eff("DS-CNN", "aimc_multi") / macro_eff("DS-CNN", "aimc_large"),
        "x (paper: >1, small arrays win on dw/pw)",
    );
    report_metric(
        "fig7/resnet8_on_aimc_large",
        macro_eff("ResNet8", "aimc_large"),
        "TOP/s/W macro-level",
    );
    let ae = results
        .iter()
        .find(|r| r.network == "DeepAutoEncoder" && r.system == "aimc_large")
        .unwrap();
    let w: f64 = ae.layers.iter().map(|l| l.best.accesses.weight_gb_reads).sum();
    let i: f64 = ae.layers.iter().map(|l| l.best.accesses.input_gb_reads).sum();
    report_metric("fig7/ae_weight_vs_input_traffic", w / i, "x (paper: >1)");

    // timing: the full grid and a single network search
    b.bench("fig7/full_case_study_16_points", || fig7_results().len());
    let systems = table2_systems();
    let net = resnet8();
    b.bench("fig7/single_network_search", || {
        search_network(&net, &systems[0], &DseOptions::default())
            .layers
            .len()
    });
    let _ = all_networks();
}
