//! Bench E3/E4/E9 (paper Fig. 5 + §V): model validation against the
//! silicon survey, per family, with the mismatch statistics.

use imcsim::arch::ImcFamily;
use imcsim::db::{validation_points, validation_stats};
use imcsim::report::fig5_text;
use imcsim::util::bench::{report_metric, Bench};

fn main() {
    let mut b = Bench::from_args();
    println!("{}", fig5_text(Some(ImcFamily::Aimc)));
    println!("{}", fig5_text(Some(ImcFamily::Dimc)));

    for (family, tag) in [
        (Some(ImcFamily::Aimc), "aimc"),
        (Some(ImcFamily::Dimc), "dimc"),
        (None, "all"),
    ] {
        let s = validation_stats(family);
        report_metric(
            &format!("fig5/{tag}/median_mismatch"),
            s.median_mismatch * 100.0,
            "%",
        );
        report_metric(
            &format!("fig5/{tag}/within_15pct"),
            s.n_within_15pct as f64 / s.n.max(1) as f64 * 100.0,
            "%",
        );
    }

    b.bench("fig5/validate_whole_survey", || validation_points(None).len());
}
