//! Bench E5/E6 (paper Fig. 6): technology-dependent parameter
//! extraction — C_inv regression and the DAC k3 fit.

use imcsim::model::tech::{
    c_inv_ff, cinv_fit_mismatches, fitted_k3_fj, linear_fit, FITTED_CINV_POINTS, K3_FJ,
};
use imcsim::report::fig6_text;
use imcsim::util::bench::{report_metric, Bench};

fn main() {
    let mut b = Bench::from_args();
    println!("{}", fig6_text());

    let worst = cinv_fit_mismatches()
        .into_iter()
        .map(|m| m.1)
        .fold(0.0f64, f64::max);
    report_metric("fig6/cinv_max_mismatch", worst * 100.0, "% (paper: ~10%)");
    report_metric(
        "fig6/k3_fit",
        fitted_k3_fj(),
        &format!("fJ (paper: {K3_FJ} fJ)"),
    );

    b.bench("fig6/regression", || {
        let pts: Vec<(f64, f64)> = FITTED_CINV_POINTS.iter().map(|p| (p.0, p.1)).collect();
        let (s, i) = linear_fit(&pts);
        s + i + c_inv_ff(28.0)
    });
}
