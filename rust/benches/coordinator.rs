//! Perf bench: the serving hot path — PJRT MVM dispatch, tiled MVM
//! throughput, and TinyCNN inference rate through the macro artifacts.
//! Skips (with a notice) when artifacts are missing.

use std::sync::Arc;

use imcsim::coordinator::{MatI32, Tensor4, Tiler, TinyCnn};
use imcsim::runtime::{default_artifacts_dir, load_manifest, Engine, Kind};
use imcsim::util::bench::{report_metric, Bench};
use imcsim::util::prng::Rng;

fn main() {
    let mut b = Bench::from_args();
    let Ok(manifest) = load_manifest(&default_artifacts_dir()) else {
        println!("coordinator bench skipped: run `make artifacts` first");
        return;
    };
    let engine = Arc::new(Engine::new(manifest).expect("PJRT client"));
    let mut rng = Rng::new(11);

    for design in ["dimc_large", "aimc_large"] {
        let d = engine.design(design).unwrap().clone();
        let rows = d.config.rows;
        let d1 = d.config.d1;
        let batch = engine.batch();
        let x: Vec<i32> = (0..batch * rows)
            .map(|_| rng.range_i64(0, 15) as i32)
            .collect();
        let w: Vec<i32> = (0..rows * d1)
            .map(|_| rng.range_i64(-8, 7) as i32)
            .collect();
        engine.execute_mvm(design, Kind::Macro, &x, &w).unwrap(); // compile
        if let Some(s) = b.bench(&format!("coord/{design}/mvm_dispatch"), || {
            engine.execute_mvm(design, Kind::Macro, &x, &w).unwrap().len()
        }) {
            let macs = (batch * rows * d1) as u64;
            report_metric(
                &format!("coord/{design}/gmacs_per_sec"),
                imcsim::util::bench::Bench::throughput(&s, macs) / 1e9,
                "GMAC/s",
            );
        }
    }

    // tiled MVM across all axes (dimc_multi is the worst-case tiler load)
    let d = engine.design("dimc_multi").unwrap().clone();
    let tiler = Tiler::new(&engine, "dimc_multi").unwrap();
    let mut x = MatI32::zeros(16, d.config.rows * 2);
    for v in &mut x.data {
        *v = rng.range_i64(0, 15) as i32;
    }
    let mut w = MatI32::zeros(d.config.rows * 2, 8);
    for v in &mut w.data {
        *v = rng.range_i64(-8, 7) as i32;
    }
    tiler.mvm(&x, &w, Kind::Macro).unwrap();
    b.bench("coord/dimc_multi/tiled_mvm_2x8_tiles", || {
        tiler.mvm(&x, &w, Kind::Macro).unwrap().1.mvms
    });

    // whole-network inference
    let d = engine.design("dimc_large").unwrap().clone();
    let tiler = Tiler::new(&engine, "dimc_large").unwrap();
    let net = TinyCnn::random(42, 16, d.config.act_bits, d.config.weight_bits);
    let imgs = Tensor4::random(&mut rng, 16, 16, 16, 1, d.config.act_bits);
    net.forward(&tiler, &imgs, Kind::Macro).unwrap();
    if let Some(s) = b.bench("coord/tinycnn_batch16_inference", || {
        net.forward(&tiler, &imgs, Kind::Macro).unwrap().2.mvms
    }) {
        report_metric(
            "coord/tinycnn_imgs_per_sec",
            imcsim::util::bench::Bench::throughput(&s, 16),
            "img/s",
        );
    }
}
