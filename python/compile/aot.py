"""AOT pipeline: lower the L2/L1 graph to HLO *text* artifacts for rust.

Runs ONCE at build time (``make artifacts``). Emits, per case-study design
(paper Table II):

* ``artifacts/<name>_mvm.hlo.txt``  — the IMC-macro MVM (pallas kernel,
  interpret-lowered so it is plain HLO ops executable on any PJRT backend),
* ``artifacts/<name>_ref.hlo.txt``  — the exact integer MVM with identical
  shapes (the rust side uses it for accuracy comparisons),

plus ``artifacts/manifest.json`` describing every artifact (shapes,
dtypes, macro parameters) so the rust runtime can load them generically.

HLO **text** (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 rejects; the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels import MacroConfig
from .model import mvm_entry, mvm_ref_entry

#: Default batch tile the coordinator pads requests to.
BATCH_TILE = 16

#: The four case-study designs of paper Table II (§VI). Macro geometry is
#: taken from the table; ADC/DAC resolutions are representative of the
#: surveyed design families (SAR ADC ~ 6-8b, DAC = full activation
#: precision for the large-array design, 2b slicing for the multi-macro
#: one; DIMC is bit-serial, dac_res = 1).
TABLE2_DESIGNS: dict[str, MacroConfig] = {
    "aimc_large": MacroConfig(
        rows=1152, cols=256, weight_bits=4, act_bits=4,
        dac_res=4, adc_res=8, family="aimc", adc_fs_rows=256,
    ),
    "aimc_multi": MacroConfig(
        rows=64, cols=32, weight_bits=4, act_bits=4,
        dac_res=2, adc_res=6, family="aimc",
    ),
    "dimc_large": MacroConfig(
        rows=256, cols=256, weight_bits=4, act_bits=4,
        dac_res=1, adc_res=0, family="dimc",
    ),
    "dimc_multi": MacroConfig(
        rows=48, cols=4, weight_bits=4, act_bits=4,
        dac_res=1, adc_res=0, family="dimc",
    ),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_mvm(cfg: MacroConfig, batch: int, exact: bool) -> str:
    """Lower one (batch, rows) x (rows, d1) MVM entry point to HLO text."""
    x_spec = jax.ShapeDtypeStruct((batch, cfg.rows), jnp.int32)
    w_spec = jax.ShapeDtypeStruct((cfg.rows, cfg.d1), jnp.int32)
    fn = mvm_ref_entry(cfg, batch) if exact else mvm_entry(cfg, batch)
    return to_hlo_text(jax.jit(fn).lower(x_spec, w_spec))


def _cfg_json(cfg: MacroConfig) -> dict:
    d = dataclasses.asdict(cfg)
    d["d1"] = cfg.d1
    d["n_slices"] = cfg.n_slices
    d["adc_lsb"] = cfg.adc_lsb if cfg.family == "aimc" else 1.0
    return d


def build_artifacts(out_dir: pathlib.Path, batch: int = BATCH_TILE) -> dict:
    """Emit all artifacts + manifest; returns the manifest dict."""
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest = {"batch": batch, "designs": {}}
    for name, cfg in TABLE2_DESIGNS.items():
        entry = {"config": _cfg_json(cfg), "files": {}}
        for kind, exact in (("mvm", False), ("ref", True)):
            text = lower_mvm(cfg, batch, exact)
            fname = f"{name}_{kind}.hlo.txt"
            (out_dir / fname).write_text(text)
            entry["files"][kind] = {
                "path": fname,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
                "inputs": [
                    {"shape": [batch, cfg.rows], "dtype": "s32"},
                    {"shape": [cfg.rows, cfg.d1], "dtype": "s32"},
                ],
                "outputs": [{"shape": [batch, cfg.d1], "dtype": "s32"}],
            }
            print(f"  wrote {fname} ({len(text)} chars)")
        manifest["designs"][name] = entry
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"  wrote manifest.json ({len(manifest['designs'])} designs)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact directory")
    ap.add_argument("--batch", type=int, default=BATCH_TILE)
    args = ap.parse_args()
    build_artifacts(pathlib.Path(args.out_dir), args.batch)


if __name__ == "__main__":
    main()
