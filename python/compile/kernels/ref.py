"""Pure-jnp correctness oracles for the IMC macro kernel (no pallas).

Two references:

* :func:`exact_matmul` — the mathematically exact integer product. DIMC
  must match it bit-exactly; AIMC must match it when the ADC has enough
  resolution (``cfg.exact_adc_res``).
* :func:`imc_macro_ref` — the same bit-serial / bit-parallel datapath as
  the pallas kernel (slicing, ADC clip+quantize, shift-add) written as
  straight-line jnp. The pallas kernel must match this one exactly in
  *all* configurations — this is the core correctness signal.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .imc_macro import MacroConfig, adc_quantize


def exact_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Exact integer (B, D2) @ (D2, D1) in int32."""
    return jnp.dot(
        x.astype(jnp.int32), w.astype(jnp.int32), preferred_element_type=jnp.int32
    )


def fast_exact_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Exact integer matmul evaluated through the f32 GEMM path.

    Bit-identical to :func:`exact_matmul` whenever every partial sum
    stays below 2^24 in magnitude (f32 represents all such integers
    exactly, so accumulation order cannot matter). All macro geometries
    in this project satisfy `rows * act_max * |w|_max < 2^24`;
    `aot.py` asserts the bound before lowering. ~4x faster than the
    int32 dot on the XLA CPU backend (EXPERIMENTS.md §Perf, L2
    iteration 1).
    """
    y = jnp.dot(
        x.astype(jnp.float32), w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return jnp.round(y).astype(jnp.int32)


def f32_exactness_bound(rows: int, act_bits: int, weight_bits: int) -> int:
    """Worst-case |partial sum| for the f32-exactness precondition."""
    return rows * (2**act_bits - 1) * 2 ** (weight_bits - 1)


def bit_planes(w: jax.Array, bits: int) -> list[jax.Array]:
    """Two's-complement bit planes (LSB first) of an int array."""
    return [((w >> jnp.int32(b)) & jnp.int32(1)) for b in range(bits)]


def input_slices(x: jax.Array, act_bits: int, dac_res: int) -> list[jax.Array]:
    """Unsigned DAC slices (LSB first) of an activation array."""
    mask = jnp.int32(2**dac_res - 1)
    n = -(-act_bits // dac_res)
    return [((x >> jnp.int32(s * dac_res)) & mask) for s in range(n)]


def reconstruct_weights(planes: list[jax.Array], bits: int) -> jax.Array:
    """Inverse of :func:`bit_planes` (two's complement)."""
    acc = jnp.zeros_like(planes[0])
    for b, p in enumerate(planes):
        scale = -(2 ** (bits - 1)) if b == bits - 1 else 2**b
        acc = acc + jnp.int32(scale) * p
    return acc


def reconstruct_inputs(slices: list[jax.Array], dac_res: int) -> jax.Array:
    """Inverse of :func:`input_slices`."""
    acc = jnp.zeros_like(slices[0])
    for s, sl in enumerate(slices):
        acc = acc + jnp.int32(2 ** (s * dac_res)) * sl
    return acc


def imc_macro_ref(x: jax.Array, w: jax.Array, cfg: MacroConfig) -> jax.Array:
    """Straight-line jnp reimplementation of the macro datapath.

    Mirrors ``imc_macro._macro_kernel`` exactly (same op order, same
    float32 accumulation) but without pallas/tiling.
    """
    x = x.astype(jnp.int32)
    w = w.astype(jnp.int32)
    acc = jnp.zeros((x.shape[0], w.shape[1]), jnp.float32)
    for s, xs in enumerate(input_slices(x, cfg.act_bits, cfg.dac_res)):
        xs = xs.astype(jnp.float32)
        for b, wb in enumerate(bit_planes(w, cfg.weight_bits)):
            bl = jnp.dot(
                xs, wb.astype(jnp.float32), preferred_element_type=jnp.float32
            )
            if cfg.family == "aimc":
                bl = adc_quantize(bl, cfg)
            plane_weight = float(2 ** (b + s * cfg.dac_res))
            if b == cfg.weight_bits - 1:
                plane_weight = -plane_weight
            acc = acc + plane_weight * bl
    return jnp.round(acc).astype(jnp.int32)
