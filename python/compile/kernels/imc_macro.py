"""Layer 1 — Pallas kernel: bit-true SRAM in-memory-computing macro datapath.

Functionally simulates one IMC macro executing a matrix-vector / matrix-matrix
multiplication the way the silicon does it (Houshmand et al., "Benchmarking
and modeling of analog and digital SRAM in-memory computing architectures"):

* Weights are stored in the array as ``B_w``-bit two's-complement words,
  one bit per SRAM column, ``D1 = C / B_w`` weight operands per row.
* Activations are unsigned ``B_a``-bit values, streamed bit-serially as
  ``ceil(B_a / DAC_res)`` slices of ``DAC_res`` bits each (the DAC width).
* Each (input-slice, weight-bit-plane) pair produces one *bitline
  accumulation*: the dot product of the slice vector with the weight bit
  plane along the ``D2`` rows of the array.
* AIMC: the bitline value is an analog charge → it passes through an ADC
  with ``ADC_res`` bits of resolution over a full-scale range of
  ``adc_fs_rows * (2^DAC_res - 1)``; values are clipped and quantized
  (this is the accuracy/efficiency trade-off of analog IMC).
* DIMC: the bitline values are digital and accumulated exactly by the
  adder tree — the result is bit-exact.
* Digital shift-and-add recombines bit planes/slices (sign bit plane has
  weight ``-2^(B_w-1)``).

The kernel runs under ``interpret=True`` (CPU) — the BlockSpec tiling
mirrors the macro geometry: one (batch-tile × D1-tile) output block per
grid step with the full accumulation axis (D2 rows) resident, i.e. the
"weights stationary / activations streamed" dataflow of the paper.

Correctness oracle: ``kernels.ref`` (pure jnp, no pallas).
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


@dataclasses.dataclass(frozen=True)
class MacroConfig:
    """Static configuration of one IMC macro (mirrors rust `arch::ImcMacro`).

    Attributes:
        rows: physical SRAM rows (the accumulation axis D2).
        cols: physical SRAM columns; ``cols // weight_bits`` weight
            operands (output channels) are stored per row.
        weight_bits: ``B_w`` — weight precision (two's complement).
        act_bits: ``B_a`` — activation precision (unsigned).
        dac_res: DAC resolution; activations are streamed in
            ``ceil(act_bits / dac_res)`` slices. DIMC designs are
            bit-serial with ``dac_res == 1`` (wordline drivers).
        adc_res: ADC resolution (AIMC only; ignored for DIMC).
        family: ``"aimc"`` or ``"dimc"``.
        adc_fs_rows: number of rows spanned by the ADC full-scale range.
            Defaults to all rows (conservative, no clipping for uniform
            inputs). Smaller values trade clipping for finer LSB.
    """

    rows: int
    cols: int
    weight_bits: int = 4
    act_bits: int = 4
    dac_res: int = 1
    adc_res: int = 8
    family: str = "aimc"
    adc_fs_rows: int | None = None

    def __post_init__(self):
        if self.family not in ("aimc", "dimc"):
            raise ValueError(f"unknown IMC family: {self.family!r}")
        if self.cols % self.weight_bits != 0:
            raise ValueError("cols must be a multiple of weight_bits")
        if not (1 <= self.dac_res <= self.act_bits):
            raise ValueError("need 1 <= dac_res <= act_bits")

    @property
    def d1(self) -> int:
        """Weight operands per row (output-channel axis of the array)."""
        return self.cols // self.weight_bits

    @property
    def d2(self) -> int:
        """Accumulation axis (rows jointly reduced per vector MAC)."""
        return self.rows

    @property
    def n_slices(self) -> int:
        """Bit-serial input slices per full-precision activation."""
        return math.ceil(self.act_bits / self.dac_res)

    @property
    def fs_rows(self) -> int:
        return self.adc_fs_rows if self.adc_fs_rows is not None else self.rows

    @property
    def adc_full_scale(self) -> float:
        """Largest bitline value representable without ADC clipping."""
        return float(self.fs_rows * (2**self.dac_res - 1))

    @property
    def adc_lsb(self) -> float:
        """ADC quantization step Δ = max(1, FS / (2^ADC_res - 1)).

        The LSB floors at 1: a bitline accumulation is an integer count of
        unit cell charges, so an ADC with more codes than the full scale
        is a lossless digitizer (Δ = 1), not a sub-unit interpolator.
        """
        return max(1.0, self.adc_full_scale / float(2**self.adc_res - 1))

    @property
    def exact_adc_res(self) -> int:
        """Smallest ADC resolution that makes AIMC bit-exact (Δ <= 1)."""
        return max(1, math.ceil(math.log2(self.adc_full_scale + 1.0)))

    def weight_range(self) -> tuple[int, int]:
        """Inclusive two's-complement weight range."""
        return (-(2 ** (self.weight_bits - 1)), 2 ** (self.weight_bits - 1) - 1)

    def act_range(self) -> tuple[int, int]:
        """Inclusive unsigned activation range."""
        return (0, 2**self.act_bits - 1)


def adc_quantize(bitline: jax.Array, cfg: MacroConfig) -> jax.Array:
    """Model of the column ADC: clip to full scale, quantize to ADC_res bits.

    ``bitline`` holds integer-valued float32 analog accumulations in
    ``[0, D2 * (2^DAC_res - 1)]``. Returns the reconstructed (de-quantized)
    value ``code * Δ`` as float32 so downstream shift-add sees what the
    digital logic would.
    """
    n_codes = 2**cfg.adc_res - 1
    fs = cfg.fs_rows * (2**cfg.dac_res - 1)
    clipped = jnp.clip(bitline, 0.0, float(fs))
    if cfg.adc_lsb <= 1.0:
        # Lossless digitizer: every unit charge maps to its own code.
        return clipped
    # Integer rounding (round-half-up): bitline values are exact integer
    # counts of unit charges, so quantization is done in int32 — bit-exact
    # and immune to 1-ulp float-division differences between jit/eager
    # evaluation (which matters for pallas-vs-ref equality). Requires
    # 2 * FS * n_codes < 2^31 (true for every surveyed geometry).
    bli = clipped.astype(jnp.int32)
    code = (2 * bli * jnp.int32(n_codes) + jnp.int32(fs)) // jnp.int32(2 * fs)
    code = jnp.clip(code, 0, n_codes)
    return code.astype(jnp.float32) * jnp.float32(cfg.adc_lsb)


def _macro_kernel(x_ref, w_ref, o_ref, *, cfg: MacroConfig):
    """Pallas kernel body: one output tile of the IMC matmul.

    x_ref: (TB, D2) int32 unsigned activations
    w_ref: (D2, TD) int32 signed weights
    o_ref: (TB, TD) int32 outputs

    Perf note (EXPERIMENTS.md §Perf, L1 iteration 2): the bit-serial /
    bit-parallel structure is evaluated as ONE stacked matmul — input
    slices concatenated along the batch axis, weight bit planes along
    the column axis — instead of `n_slices x weight_bits` separate
    matmuls. Every output element is the same dot product of the same
    0/1-valued vectors (all values are integers < 2^24, so f32
    accumulation is exact regardless of association), so the result is
    bit-identical to the loop form used by `ref.imc_macro_ref`; the
    stacked GEMM simply blocks far better on the CPU backend.
    """
    x = x_ref[...]
    w = w_ref[...]
    tb = x.shape[0]
    td = w.shape[1]
    slice_mask = jnp.int32(2**cfg.dac_res - 1)

    # (S*TB, D2): input DAC slices stacked on the batch axis
    xs = jnp.concatenate(
        [
            ((x >> jnp.int32(s * cfg.dac_res)) & slice_mask).astype(jnp.float32)
            for s in range(cfg.n_slices)
        ],
        axis=0,
    )
    # (D2, BW*TD): two's-complement bit planes stacked on the column
    # axis. Arithmetic >> keeps the sign replicated, so the
    # (weight_bits-1)-th plane is the sign plane.
    wp = jnp.concatenate(
        [
            ((w >> jnp.int32(b)) & jnp.int32(1)).astype(jnp.float32)
            for b in range(cfg.weight_bits)
        ],
        axis=1,
    )
    # Analog (AIMC) / digital (DIMC) accumulation along the rows for all
    # (slice, plane) pairs at once.
    bl = jnp.dot(xs, wp, preferred_element_type=jnp.float32)
    if cfg.family == "aimc":
        bl = adc_quantize(bl, cfg)
    # (S, TB, BW, TD): rows are slice-major, columns plane-major
    bl = bl.reshape(cfg.n_slices, tb, cfg.weight_bits, td)

    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for s in range(cfg.n_slices):
        for b in range(cfg.weight_bits):
            plane_weight = float(2 ** (b + s * cfg.dac_res))
            if b == cfg.weight_bits - 1:
                plane_weight = -plane_weight  # sign plane
            acc = acc + plane_weight * bl[s, :, b, :]
    o_ref[...] = jnp.round(acc).astype(jnp.int32)


def _pad_to(n: int, multiple: int) -> int:
    return ((n + multiple - 1) // multiple) * multiple


@functools.partial(jax.jit, static_argnames=("cfg", "tile_b", "tile_d1"))
def imc_macro_matmul(
    x: jax.Array,
    w: jax.Array,
    cfg: MacroConfig,
    tile_b: int = 16,
    tile_d1: int | None = None,
) -> jax.Array:
    """Run the IMC macro on a (B, D2) x (D2, D1) integer matmul.

    Args:
        x: (B, D2) int32 unsigned activations in ``cfg.act_range()``.
        w: (D2, D1) int32 signed weights in ``cfg.weight_range()``.
        cfg: macro configuration; ``D2 == cfg.rows`` and ``D1 <= cfg.d1``
            are enforced (a smaller D1 models a partially-filled array).
    Returns:
        (B, D1) int32: the macro's output after ADC + shift-add (AIMC) or
        the exact product (DIMC).
    """
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError("x must be (B, D2), w must be (D2, D1)")
    if x.shape[1] != cfg.rows or w.shape[0] != cfg.rows:
        raise ValueError(
            f"accumulation axis mismatch: x {x.shape}, w {w.shape}, rows={cfg.rows}"
        )
    if w.shape[1] > cfg.d1:
        raise ValueError(f"D1={w.shape[1]} exceeds macro capacity {cfg.d1}")

    b, d1 = x.shape[0], w.shape[1]
    td = tile_d1 if tile_d1 is not None else min(d1, 128)
    pb, pd = _pad_to(b, tile_b), _pad_to(d1, td)
    xp = jnp.pad(x.astype(jnp.int32), ((0, pb - b), (0, 0)))
    wp = jnp.pad(w.astype(jnp.int32), ((0, 0), (0, pd - d1)))

    out = pl.pallas_call(
        functools.partial(_macro_kernel, cfg=cfg),
        grid=(pb // tile_b, pd // td),
        in_specs=[
            pl.BlockSpec((tile_b, cfg.rows), lambda i, j: (i, 0)),
            pl.BlockSpec((cfg.rows, td), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tile_b, td), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pb, pd), jnp.int32),
        interpret=True,  # CPU path; real-TPU lowering would emit Mosaic.
    )(xp, wp)
    return out[:b, :d1]


def macro_output_bound(cfg: MacroConfig, d2: int | None = None) -> int:
    """Worst-case |output| of one macro reduction (for requant scaling)."""
    d2 = cfg.rows if d2 is None else d2
    amax = 2**cfg.act_bits - 1
    wmax = 2 ** (cfg.weight_bits - 1)
    return d2 * amax * wmax


def aimc_error_bound(cfg: MacroConfig) -> float:
    """Upper bound on |AIMC output - exact| from ADC quantization alone.

    Each of the ``n_slices * weight_bits`` bitline conversions contributes
    at most Δ/2 absolute error (no clipping assumed), scaled by its
    shift-add plane weight. Clipping can add more; with
    ``adc_fs_rows == rows`` and in-range operands there is no clipping.
    """
    delta = cfg.adc_lsb
    total = 0.0
    for s in range(cfg.n_slices):
        for b in range(cfg.weight_bits):
            total += (delta / 2.0) * float(2 ** (b + s * cfg.dac_res))
    return total
