"""L1 pallas kernels: bit-true IMC macro datapath + pure-jnp oracles."""

from .imc_macro import (  # noqa: F401
    MacroConfig,
    adc_quantize,
    aimc_error_bound,
    imc_macro_matmul,
    macro_output_bound,
)
from .ref import exact_matmul, imc_macro_ref  # noqa: F401
