"""Layer 2 — JAX model: quantized DNN layers executed *through* the IMC macro.

This is the compute graph that gets AOT-lowered to HLO text and executed by
the rust runtime. It expresses DNN layers the way an IMC system maps them
(paper §II, Fig. 2):

* the K (output-channel) loop is unrolled across the macro columns (D1),
* the C·FX·FY (reduction) loops are unrolled across the macro rows (D2),
* reductions larger than D2 are split into row-tiles whose partial sums
  are accumulated *digitally outside the array* — exactly what the
  coordinator (L3) schedules, and what the analytical model charges as
  extra partial-sum traffic.

Everything is integer-quantized (unsigned ``act_bits`` activations,
signed ``weight_bits`` weights) so the macro kernel sees in-range
operands. Python here runs at *build time only*.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels import MacroConfig, imc_macro_matmul
from .kernels.ref import exact_matmul, f32_exactness_bound, fast_exact_matmul


# --------------------------------------------------------------------------
# Quantization helpers
# --------------------------------------------------------------------------


def quantize_act(x: jax.Array, act_bits: int) -> jax.Array:
    """Clip a non-negative integer tensor into the unsigned act range."""
    return jnp.clip(x.astype(jnp.int32), 0, 2**act_bits - 1)


def quantize_weight(w: jax.Array, weight_bits: int) -> jax.Array:
    """Clip an integer tensor into the signed two's-complement range."""
    lo, hi = -(2 ** (weight_bits - 1)), 2 ** (weight_bits - 1) - 1
    return jnp.clip(w.astype(jnp.int32), lo, hi)


def requantize(acc: jax.Array, shift: int, act_bits: int) -> jax.Array:
    """Requantize a wide accumulator to the next layer's activation range.

    Arithmetic right-shift + ReLU + clip — the standard integer-only
    post-processing pipeline of edge inference (the "digital SIMD" block
    next to the macro).
    """
    return jnp.clip(acc >> jnp.int32(shift), 0, 2**act_bits - 1)


# --------------------------------------------------------------------------
# MVM with row/column tiling onto the macro geometry
# --------------------------------------------------------------------------


def tiled_mvm(
    x: jax.Array, w: jax.Array, cfg: MacroConfig, exact: bool = False
) -> jax.Array:
    """(B, R_total) @ (R_total, K) through D2xD1 macro tiles.

    Splits the reduction axis into ``ceil(R_total / D2)`` row-tiles (each a
    separate macro invocation, partial sums accumulated digitally) and the
    output axis into ``ceil(K / D1)`` column-tiles. Zero-pads the last
    row-tile — pad rows contribute 0 to every bitline, which is also what
    unused (power-gated) rows contribute in silicon.
    """
    b, r_total = x.shape
    k = w.shape[1]
    d2, d1 = cfg.rows, cfg.d1
    n_row_tiles = -(-r_total // d2)

    pad_r = n_row_tiles * d2 - r_total
    xp = jnp.pad(x, ((0, 0), (0, pad_r)))
    wp = jnp.pad(w, ((0, pad_r), (0, 0)))

    acc = jnp.zeros((b, k), jnp.int32)
    for rt in range(n_row_tiles):
        xs = xp[:, rt * d2 : (rt + 1) * d2]
        ws = wp[rt * d2 : (rt + 1) * d2, :]
        for ct in range(-(-k // d1)):
            wc = ws[:, ct * d1 : (ct + 1) * d1]
            if exact:
                part = exact_matmul(xs, wc)
            else:
                part = imc_macro_matmul(xs, wc, cfg)
            acc = acc.at[:, ct * d1 : ct * d1 + wc.shape[1]].add(part)
    return acc


# --------------------------------------------------------------------------
# Layers
# --------------------------------------------------------------------------


def im2col(x: jax.Array, fy: int, fx: int, stride: int = 1) -> jax.Array:
    """(B, H, W, C) -> (B*OY*OX, FY*FX*C) patch matrix (valid padding)."""
    b, h, w, c = x.shape
    oy, ox = (h - fy) // stride + 1, (w - fx) // stride + 1
    patches = []
    for iy in range(fy):
        for ix in range(fx):
            patches.append(
                x[:, iy : iy + stride * oy : stride, ix : ix + stride * ox : stride, :]
            )
    # (B, OY, OX, FY*FX*C) -> flatten spatial into batch
    stacked = jnp.concatenate(patches, axis=-1)
    return stacked.reshape(b * oy * ox, fy * fx * c), (b, oy, ox)


def conv2d_via_macro(
    x: jax.Array,
    w: jax.Array,
    cfg: MacroConfig,
    stride: int = 1,
    exact: bool = False,
) -> jax.Array:
    """Integer conv2d (B,H,W,C)·(FY,FX,C,K) -> (B,OY,OX,K) on the macro.

    The im2col lowering realizes the paper's spatial unrolling: the
    FY·FX·C reduction lands on the macro rows, K on the columns, and the
    B·OY·OX loop runs temporally (one MVM per output pixel vector).
    """
    fy, fx, c, k = w.shape
    cols, (b, oy, ox) = im2col(x, fy, fx, stride)
    wmat = w.reshape(fy * fx * c, k)
    out = tiled_mvm(cols, wmat, cfg, exact=exact)
    return out.reshape(b, oy, ox, k)


def dense_via_macro(
    x: jax.Array, w: jax.Array, cfg: MacroConfig, exact: bool = False
) -> jax.Array:
    """Integer dense (B, C)·(C, K) on the macro."""
    return tiled_mvm(x, w, cfg, exact=exact)


def avg_pool_int(x: jax.Array, size: int) -> jax.Array:
    """Integer average pool (floor division) over size x size windows."""
    b, h, w, c = x.shape
    oh, ow = h // size, w // size
    xr = x[:, : oh * size, : ow * size, :].reshape(b, oh, size, ow, size, c)
    return xr.sum(axis=(2, 4)) // jnp.int32(size * size)


# --------------------------------------------------------------------------
# TinyCNN — the end-to-end functional workload (E10)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TinyCnnSpec:
    """A small integer CNN (MNIST-like 16x16x1 input, 10 classes)."""

    act_bits: int = 4
    weight_bits: int = 4
    c1: int = 8  # conv1 output channels (3x3)
    c2: int = 16  # conv2 output channels (3x3, stride 2)
    classes: int = 10
    image: int = 16

    def param_shapes(self) -> dict[str, tuple[int, ...]]:
        flat = ((self.image - 2 - 3) // 2 + 1) ** 2 * self.c2
        return {
            "conv1": (3, 3, 1, self.c1),
            "conv2": (3, 3, self.c1, self.c2),
            "dense": (flat, self.classes),
        }


def tiny_cnn_init(spec: TinyCnnSpec, seed: int = 0) -> dict[str, jax.Array]:
    """Random integer weights in the signed range (deterministic)."""
    key = jax.random.PRNGKey(seed)
    params = {}
    lo, hi = -(2 ** (spec.weight_bits - 1)), 2 ** (spec.weight_bits - 1)
    for name, shape in spec.param_shapes().items():
        key, sub = jax.random.split(key)
        params[name] = jax.random.randint(sub, shape, lo, hi, dtype=jnp.int32)
    return params


def tiny_cnn_forward(
    params: dict[str, jax.Array],
    x: jax.Array,
    spec: TinyCnnSpec,
    cfg: MacroConfig,
    exact: bool = False,
) -> jax.Array:
    """Integer forward pass, every MVM routed through the IMC macro.

    Requant shifts are sized so each layer's accumulator fits back into
    the activation range for worst-case-ish magnitudes.
    """
    h = conv2d_via_macro(x, params["conv1"], cfg, exact=exact)
    h = requantize(h, shift=4, act_bits=spec.act_bits)
    h = conv2d_via_macro(h, params["conv2"], cfg, stride=2, exact=exact)
    h = requantize(h, shift=6, act_bits=spec.act_bits)
    h = h.reshape(h.shape[0], -1)
    return dense_via_macro(h, params["dense"], cfg, exact=exact)


# --------------------------------------------------------------------------
# AOT entry points (what aot.py lowers; what rust executes)
# --------------------------------------------------------------------------


def _assert_f32_exact(cfg: MacroConfig) -> None:
    bound = f32_exactness_bound(cfg.rows, cfg.act_bits, cfg.weight_bits)
    assert bound < 2**24, (
        f"f32 GEMM path not exact for this geometry (bound {bound} >= 2^24)"
    )


def mvm_entry(cfg: MacroConfig, batch: int, fused: bool | None = None):
    """Returns fn(x:(batch,rows) i32, w:(rows,d1) i32) -> ((batch,d1) i32,).

    ``fused`` (default: True for DIMC) lowers the macro as one exact f32
    GEMM instead of the bit-serial datapath graph. For DIMC the two are
    bit-identical by construction — the adder tree is exact, proven by
    the kernel test suite (`test_dimc_is_exact`,
    `test_fused_dimc_entry_equals_bit_true`) — so this is a pure
    compile-time optimization (EXPERIMENTS.md §Perf, L2 iteration 1).
    AIMC always lowers the bit-true datapath (ADC quantization is the
    behaviour under study).
    """
    if fused is None:
        fused = cfg.family == "dimc"
    if fused and cfg.family == "dimc":
        _assert_f32_exact(cfg)

        @functools.partial(jax.jit)
        def fn(x, w):
            return (fast_exact_matmul(quantize_act(x, cfg.act_bits),
                                      quantize_weight(w, cfg.weight_bits)),)

        return fn

    @functools.partial(jax.jit)
    def fn(x, w):
        return (imc_macro_matmul(quantize_act(x, cfg.act_bits),
                                 quantize_weight(w, cfg.weight_bits), cfg),)

    return fn


def mvm_ref_entry(cfg: MacroConfig, batch: int):
    """Exact-matmul twin of :func:`mvm_entry` (same shapes/dtypes)."""
    _assert_f32_exact(cfg)

    @functools.partial(jax.jit)
    def fn(x, w):
        return (fast_exact_matmul(quantize_act(x, cfg.act_bits),
                                  quantize_weight(w, cfg.weight_bits)),)

    return fn
