"""L2 correctness: tiled layers through the macro vs dense references."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import MacroConfig, exact_matmul
from compile import model as M

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")

DIMC_SMALL = MacroConfig(rows=16, cols=16, weight_bits=4, act_bits=4,
                         dac_res=1, adc_res=0, family="dimc")


def rand_xw(rng, b, r, k):
    x = jnp.asarray(rng.integers(0, 16, (b, r)), jnp.int32)
    w = jnp.asarray(rng.integers(-8, 8, (r, k)), jnp.int32)
    return x, w


@given(
    seed=st.integers(0, 2**31 - 1),
    r_total=st.sampled_from([5, 16, 17, 40, 64]),
    k=st.sampled_from([1, 3, 4, 9]),
)
def test_tiled_mvm_row_col_tiling_is_exact(seed, r_total, k):
    """Row-tile partial sums accumulated digitally == full matmul (DIMC)."""
    rng = np.random.default_rng(seed)
    x, w = rand_xw(rng, 6, r_total, k)
    out = M.tiled_mvm(x, w, DIMC_SMALL)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exact_matmul(x, w)))


@given(
    seed=st.integers(0, 2**31 - 1),
    c=st.sampled_from([1, 3]),
    k=st.sampled_from([2, 5]),
    stride=st.sampled_from([1, 2]),
)
def test_conv2d_via_macro_matches_lax_conv(seed, c, k, stride):
    """im2col + macro tiling == jax.lax general conv (integer, DIMC)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 16, (2, 9, 9, c)), jnp.int32)
    w = jnp.asarray(rng.integers(-8, 8, (3, 3, c, k)), jnp.int32)
    got = M.conv2d_via_macro(x, w, DIMC_SMALL, stride=stride)
    want = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_dense_via_macro_exact_flag():
    rng = np.random.default_rng(3)
    x, w = rand_xw(rng, 4, 33, 7)
    np.testing.assert_array_equal(
        np.asarray(M.dense_via_macro(x, w, DIMC_SMALL, exact=True)),
        np.asarray(exact_matmul(x, w)),
    )


def test_requantize_range_and_relu():
    acc = jnp.asarray([[-100, 0, 15, 16, 1000]], jnp.int32)
    out = np.asarray(M.requantize(acc, shift=0, act_bits=4))
    assert out.min() >= 0 and out.max() <= 15
    assert out[0, 0] == 0  # negative clipped (ReLU)
    out2 = np.asarray(M.requantize(jnp.asarray([[64]], jnp.int32), 3, 4))
    assert out2[0, 0] == 8  # 64 >> 3


def test_avg_pool_int():
    x = jnp.arange(16, dtype=jnp.int32).reshape(1, 4, 4, 1)
    out = np.asarray(M.avg_pool_int(x, 2))
    # window [[0,1],[4,5]] -> 10//4 = 2
    assert out.shape == (1, 2, 2, 1) and out[0, 0, 0, 0] == 2


def test_tiny_cnn_forward_dimc_matches_exact():
    """On DIMC the whole network is bit-exact vs the exact=True path."""
    spec = M.TinyCnnSpec(image=12)
    params = M.tiny_cnn_init(spec, seed=1)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 16, (2, 12, 12, 1)), jnp.int32)
    got = M.tiny_cnn_forward(params, x, spec, DIMC_SMALL)
    want = M.tiny_cnn_forward(params, x, spec, DIMC_SMALL, exact=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_tiny_cnn_aimc_close_to_exact():
    """A reasonably-sized ADC keeps AIMC logits near the exact ones."""
    spec = M.TinyCnnSpec(image=12)
    params = M.tiny_cnn_init(spec, seed=1)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 16, (2, 12, 12, 1)), jnp.int32)
    aimc = MacroConfig(rows=16, cols=16, weight_bits=4, act_bits=4,
                       dac_res=2, adc_res=8, family="aimc")
    got = np.asarray(M.tiny_cnn_forward(params, x, spec, aimc))
    want = np.asarray(M.tiny_cnn_forward(params, x, spec, aimc, exact=True))
    # int4 requant between layers absorbs small ADC error; logits within 15%.
    denom = np.maximum(np.abs(want).max(), 1)
    assert np.abs(got - want).max() / denom < 0.15


def test_tiny_cnn_param_shapes():
    spec = M.TinyCnnSpec(image=16)
    shapes = spec.param_shapes()
    params = M.tiny_cnn_init(spec)
    assert {k: tuple(v.shape) for k, v in params.items()} == shapes


def test_fused_dimc_entry_equals_bit_true():
    """The fused (f32 GEMM) DIMC lowering is bit-identical to the
    bit-serial datapath graph — the equivalence behind the L2 perf
    optimization (EXPERIMENTS.md §Perf)."""
    from compile.model import mvm_entry

    cfg = MacroConfig(rows=48, cols=16, weight_bits=4, act_bits=4,
                      dac_res=1, adc_res=0, family="dimc")
    fused = mvm_entry(cfg, batch=8, fused=True)
    bit_true = mvm_entry(cfg, batch=8, fused=False)
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.integers(0, 16, (8, 48)), jnp.int32)
    w = jnp.asarray(rng.integers(-8, 8, (48, 4)), jnp.int32)
    np.testing.assert_array_equal(np.asarray(fused(x, w)[0]),
                                  np.asarray(bit_true(x, w)[0]))


def test_fast_exact_matmul_property():
    """f32 GEMM path == int32 path at worst-case magnitudes."""
    from compile.kernels.ref import fast_exact_matmul, f32_exactness_bound

    # worst case for the largest geometry in the project
    assert f32_exactness_bound(1152, 4, 4) < 2**24
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.integers(0, 16, (4, 1152)), jnp.int32)
    w = jnp.asarray(rng.integers(-8, 8, (1152, 8)), jnp.int32)
    # include all-max corner
    x = x.at[0].set(15)
    w = w.at[:, 0].set(-8)
    np.testing.assert_array_equal(np.asarray(fast_exact_matmul(x, w)),
                                  np.asarray(exact_matmul(x, w)))
