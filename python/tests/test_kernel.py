"""L1 correctness: pallas macro kernel vs pure-jnp oracles.

The core signals:
  1. pallas kernel == straight-line jnp datapath (imc_macro_ref), exactly,
     for ALL configurations (hypothesis sweep over geometry/precision).
  2. DIMC == exact integer matmul, bit-exactly.
  3. AIMC == exact matmul when the ADC is lossless (adc_res >= exact_adc_res).
  4. AIMC quantization error is bounded by the analytical bound.
  5. bit-decomposition round-trips.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    MacroConfig,
    aimc_error_bound,
    exact_matmul,
    imc_macro_matmul,
    imc_macro_ref,
)
from compile.kernels import ref as R

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rand_operands(rng, b, rows, d1, cfg):
    alo, ahi = cfg.act_range()
    wlo, whi = cfg.weight_range()
    x = jnp.asarray(rng.integers(alo, ahi + 1, (b, rows)), jnp.int32)
    w = jnp.asarray(rng.integers(wlo, whi + 1, (rows, d1)), jnp.int32)
    return x, w


# -- strategy over valid macro configs ------------------------------------

families = st.sampled_from(["aimc", "dimc"])


@st.composite
def macro_configs(draw):
    weight_bits = draw(st.sampled_from([2, 4, 8]))
    act_bits = draw(st.sampled_from([2, 4, 8]))
    dac_res = draw(st.sampled_from([1, 2, act_bits]))
    rows = draw(st.sampled_from([16, 48, 64, 96]))
    d1 = draw(st.integers(1, 8))
    family = draw(families)
    adc_res = draw(st.integers(3, 10))
    return MacroConfig(
        rows=rows,
        cols=d1 * weight_bits,
        weight_bits=weight_bits,
        act_bits=act_bits,
        dac_res=min(dac_res, act_bits),
        adc_res=adc_res,
        family=family,
    )


@given(cfg=macro_configs(), seed=st.integers(0, 2**31 - 1), b=st.integers(1, 9))
def test_pallas_matches_jnp_datapath(cfg, seed, b):
    """Signal 1: pallas kernel is exactly the jnp datapath, any config."""
    rng = np.random.default_rng(seed)
    x, w = rand_operands(rng, b, cfg.rows, cfg.d1, cfg)
    out = imc_macro_matmul(x, w, cfg, tile_b=4, tile_d1=min(cfg.d1, 4))
    ref = imc_macro_ref(x, w, cfg)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@given(
    seed=st.integers(0, 2**31 - 1),
    rows=st.sampled_from([16, 64, 128]),
    weight_bits=st.sampled_from([2, 4, 8]),
    act_bits=st.sampled_from([2, 4, 8]),
)
def test_dimc_is_exact(seed, rows, weight_bits, act_bits):
    """Signal 2: the digital adder tree never loses a bit."""
    cfg = MacroConfig(
        rows=rows, cols=8 * weight_bits, weight_bits=weight_bits,
        act_bits=act_bits, dac_res=1, adc_res=0, family="dimc",
    )
    rng = np.random.default_rng(seed)
    x, w = rand_operands(rng, 5, rows, 8, cfg)
    np.testing.assert_array_equal(
        np.asarray(imc_macro_matmul(x, w, cfg)), np.asarray(exact_matmul(x, w))
    )


@given(seed=st.integers(0, 2**31 - 1), dac_res=st.sampled_from([1, 2, 4]))
def test_aimc_lossless_adc_is_exact(seed, dac_res):
    """Signal 3: with adc_res >= exact_adc_res, AIMC == exact."""
    base = MacroConfig(rows=64, cols=32, dac_res=dac_res, family="aimc")
    cfg = MacroConfig(
        rows=64, cols=32, dac_res=dac_res, family="aimc",
        adc_res=base.exact_adc_res,
    )
    rng = np.random.default_rng(seed)
    x, w = rand_operands(rng, 6, 64, 8, cfg)
    np.testing.assert_array_equal(
        np.asarray(imc_macro_matmul(x, w, cfg)), np.asarray(exact_matmul(x, w))
    )


@given(seed=st.integers(0, 2**31 - 1), adc_res=st.integers(4, 10))
def test_aimc_error_within_bound(seed, adc_res):
    """Signal 4: |AIMC - exact| <= analytical quantization bound."""
    cfg = MacroConfig(rows=128, cols=32, adc_res=adc_res, family="aimc")
    rng = np.random.default_rng(seed)
    x, w = rand_operands(rng, 8, 128, 8, cfg)
    err = np.abs(
        np.asarray(imc_macro_matmul(x, w, cfg)) - np.asarray(exact_matmul(x, w))
    ).max()
    assert float(err) <= aimc_error_bound(cfg) + 1.0  # +1 for final rounding


def test_aimc_clipping_saturates_not_wraps():
    """A clipped ADC saturates: output underestimates, never overflows."""
    cfg = MacroConfig(
        rows=64, cols=8, weight_bits=4, act_bits=4, dac_res=4,
        adc_res=4, family="aimc", adc_fs_rows=4,  # tiny FS -> heavy clipping
    )
    x = jnp.full((2, 64), 15, jnp.int32)  # all-max inputs
    w = jnp.full((64, 2), 7, jnp.int32)  # all-max positive weights
    out = np.asarray(imc_macro_matmul(x, w, cfg))
    exact = np.asarray(exact_matmul(x, w))
    assert (out <= exact).all() and (out >= 0).all()


@given(
    seed=st.integers(0, 2**31 - 1),
    bits=st.sampled_from([2, 3, 4, 8]),
)
def test_bit_plane_roundtrip(seed, bits):
    """Signal 5a: two's-complement decomposition is exact."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(
        rng.integers(-(2 ** (bits - 1)), 2 ** (bits - 1), (13, 7)), jnp.int32
    )
    planes = R.bit_planes(w, bits)
    np.testing.assert_array_equal(
        np.asarray(R.reconstruct_weights(planes, bits)), np.asarray(w)
    )


@given(
    seed=st.integers(0, 2**31 - 1),
    act_bits=st.sampled_from([2, 4, 8]),
    dac_res=st.sampled_from([1, 2, 3, 4]),
)
def test_input_slice_roundtrip(seed, act_bits, dac_res):
    """Signal 5b: DAC slicing decomposition is exact."""
    dac_res = min(dac_res, act_bits)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 2**act_bits, (11, 5)), jnp.int32)
    slices = R.input_slices(x, act_bits, dac_res)
    np.testing.assert_array_equal(
        np.asarray(R.reconstruct_inputs(slices, dac_res)), np.asarray(x)
    )


def test_uneven_tiling_padding():
    """Odd B / D1 not divisible by tiles must still be exact (DIMC)."""
    cfg = MacroConfig(rows=48, cols=4 * 3, weight_bits=4, act_bits=4,
                      dac_res=1, adc_res=0, family="dimc")
    rng = np.random.default_rng(7)
    x, w = rand_operands(rng, 13, 48, 3, cfg)
    np.testing.assert_array_equal(
        np.asarray(imc_macro_matmul(x, w, cfg, tile_b=8, tile_d1=2)),
        np.asarray(exact_matmul(x, w)),
    )


def test_adc_lsb_floors_at_one():
    cfg = MacroConfig(rows=8, cols=8, dac_res=1, adc_res=12, family="aimc")
    assert cfg.adc_lsb == 1.0
    big = MacroConfig(rows=1024, cols=8, dac_res=4, adc_res=4, family="aimc")
    assert big.adc_lsb > 1.0


def test_config_validation():
    with pytest.raises(ValueError):
        MacroConfig(rows=8, cols=7, weight_bits=4)  # cols % bw != 0
    with pytest.raises(ValueError):
        MacroConfig(rows=8, cols=8, family="quantum")
    with pytest.raises(ValueError):
        MacroConfig(rows=8, cols=8, act_bits=4, dac_res=5)


def test_shape_validation():
    cfg = MacroConfig(rows=16, cols=16)
    x = jnp.zeros((4, 8), jnp.int32)  # wrong D2
    w = jnp.zeros((16, 4), jnp.int32)
    with pytest.raises(ValueError):
        imc_macro_matmul(x, w, cfg)
    with pytest.raises(ValueError):
        imc_macro_matmul(jnp.zeros((4, 16), jnp.int32),
                         jnp.zeros((16, 99), jnp.int32), cfg)  # D1 too big
