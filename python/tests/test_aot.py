"""AOT pipeline: HLO text artifacts are well-formed and manifest-consistent."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.kernels import MacroConfig
from compile.model import mvm_entry


def test_table2_designs_match_paper():
    """Macro geometries are the ones from paper Table II."""
    t = aot.TABLE2_DESIGNS
    assert (t["aimc_large"].rows, t["aimc_large"].cols) == (1152, 256)
    assert (t["aimc_multi"].rows, t["aimc_multi"].cols) == (64, 32)
    assert (t["dimc_large"].rows, t["dimc_large"].cols) == (256, 256)
    assert (t["dimc_multi"].rows, t["dimc_multi"].cols) == (48, 4)
    for cfg in t.values():
        assert cfg.weight_bits == 4 and cfg.act_bits == 4


def test_lower_mvm_produces_hlo_text():
    cfg = MacroConfig(rows=16, cols=16, family="dimc", dac_res=1, adc_res=0)
    text = aot.lower_mvm(cfg, batch=4, exact=False)
    assert "HloModule" in text
    assert "ENTRY" in text
    # int32 interface, tuple return (rust unwraps with to_tuple1)
    assert "s32[4,16]" in text and "s32[16,4]" in text


def test_build_artifacts_manifest(tmp_path: pathlib.Path):
    # Use a tiny design set by monkeypatching would hide bugs; build one
    # real (small) design instead.
    small = {"dimc_small": MacroConfig(rows=16, cols=16, family="dimc",
                                       dac_res=1, adc_res=0)}
    orig = aot.TABLE2_DESIGNS
    try:
        aot.TABLE2_DESIGNS = small
        manifest = aot.build_artifacts(tmp_path, batch=4)
    finally:
        aot.TABLE2_DESIGNS = orig
    m = json.loads((tmp_path / "manifest.json").read_text())
    assert m == manifest
    entry = m["designs"]["dimc_small"]
    for kind in ("mvm", "ref"):
        f = entry["files"][kind]
        assert (tmp_path / f["path"]).exists()
        assert f["inputs"][0]["shape"] == [4, 16]
        assert f["outputs"][0]["shape"] == [4, 4]
    assert entry["config"]["d1"] == 4


def test_lowered_mvm_executes_like_kernel():
    """The jitted AOT entry point returns the macro kernel's numbers."""
    cfg = MacroConfig(rows=16, cols=16, family="aimc", dac_res=2, adc_res=6)
    fn = mvm_entry(cfg, batch=4)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 16, (4, 16)), jnp.int32)
    w = jnp.asarray(rng.integers(-8, 8, (16, 4)), jnp.int32)
    (out,) = fn(x, w)
    from compile.kernels import imc_macro_ref

    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(imc_macro_ref(x, w, cfg)))


def test_entry_point_clips_out_of_range_operands():
    """The AOT entry quantizes, so hostile inputs can't break invariants."""
    cfg = MacroConfig(rows=16, cols=16, family="dimc", dac_res=1, adc_res=0)
    fn = mvm_entry(cfg, batch=2)
    x = jnp.full((2, 16), 9999, jnp.int32)
    w = jnp.full((16, 4), -9999, jnp.int32)
    (out,) = fn(x, w)
    # clipped to 15 * -8 * 16 rows
    assert int(out[0, 0]) == 15 * -8 * 16
